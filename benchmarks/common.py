"""Shared benchmark utilities: the CPU-scale BERT stand-in + runners.

The paper's experiments are BERT-Large on a TPUv3 pod; this box is one CPU
core.  Every claims benchmark therefore runs a ~10M-param BERT-family model
on the deterministic synthetic corpus, holding the paper's *protocol* fixed:
same epochs across batch sizes, steps = tokens/batch, sqrt-LR scaling +
linear-epoch warmup, untuned LAMB vs grid-tuned baselines.  What is validated
is the *shape* of the paper's claims, not absolute F1.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.bert_large import tiny as bert_tiny
from repro.telemetry import run_provenance


def provenance_header(timestamp: float, *, mesh=None) -> Dict:
    """The shared header every ``BENCH_*.json`` carries: git sha, caller's
    timestamp, jax/jaxlib versions, device kind, and the mesh spec — so two
    bench blobs are comparable only when their environments are."""
    return run_provenance(timestamp=timestamp, mesh=mesh)


def bert_cpu(seq_len: int = 64, vocab: int = 1024):
    """~6M-param BERT-family encoder for CPU benches."""
    return bert_tiny(vocab=vocab).replace(
        name="bert-cpu", n_layers=2, d_model=192, n_heads=4, n_kv_heads=4,
        d_ff=512,
    )


def bert_nano(vocab: int = 512):
    """~1.5M-param encoder: saturates within CPU step budgets."""
    return bert_tiny(vocab=vocab).replace(
        name="bert-nano", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256,
    )


def fixed_epoch_steps(total_tokens: int, batch: int, seq: int) -> int:
    """The paper's protocol: fixed epochs ⇒ steps shrink as batch grows."""
    return max(total_tokens // (batch * seq), 2)


def train_once(cfg, **kw) -> Dict[str, float]:
    """Train and return final train loss + held-out eval loss/accuracy.

    Forwards to :func:`benchmarks.protocol.train_once`, so every table bench
    runs the full fused production path (flash attention, fused CE head,
    fused LAMB) — see that module for the extra knobs (accum_steps,
    precision, target_loss) and the ``history`` trajectory it adds.
    """
    from benchmarks.protocol import train_once as _protocol_train_once

    return _protocol_train_once(cfg, **kw)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
