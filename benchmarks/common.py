"""Shared benchmark utilities: the CPU-scale BERT stand-in + runners.

The paper's experiments are BERT-Large on a TPUv3 pod; this box is one CPU
core.  Every claims benchmark therefore runs a ~10M-param BERT-family model
on the deterministic synthetic corpus, holding the paper's *protocol* fixed:
same epochs across batch sizes, steps = tokens/batch, sqrt-LR scaling +
linear-epoch warmup, untuned LAMB vs grid-tuned baselines.  What is validated
is the *shape* of the paper's claims, not absolute F1.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.bert_large import tiny as bert_tiny
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.data.synthetic import SyntheticLM
from repro.models import build_model
from repro.telemetry import run_provenance
from repro.train import Trainer


def provenance_header(timestamp: float, *, mesh=None) -> Dict:
    """The shared header every ``BENCH_*.json`` carries: git sha, caller's
    timestamp, jax/jaxlib versions, device kind, and the mesh spec — so two
    bench blobs are comparable only when their environments are."""
    return run_provenance(timestamp=timestamp, mesh=mesh)


def bert_cpu(seq_len: int = 64, vocab: int = 1024):
    """~6M-param BERT-family encoder for CPU benches."""
    return bert_tiny(vocab=vocab).replace(
        name="bert-cpu", n_layers=2, d_model=192, n_heads=4, n_kv_heads=4,
        d_ff=512,
    )


def bert_nano(vocab: int = 512):
    """~1.5M-param encoder: saturates within CPU step budgets."""
    return bert_tiny(vocab=vocab).replace(
        name="bert-nano", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256,
    )


def fixed_epoch_steps(total_tokens: int, batch: int, seq: int) -> int:
    """The paper's protocol: fixed epochs ⇒ steps shrink as batch grows."""
    return max(total_tokens // (batch * seq), 2)


def train_once(
    cfg,
    *,
    optimizer: str,
    batch: int,
    seq: int,
    steps: int,
    lr: float,
    warmup_ratio: float,
    seed: int = 0,
    eval_batches: int = 4,
    weight_decay: float = 0.01,
) -> Dict[str, float]:
    """Train and return final train loss + held-out eval loss/accuracy."""
    model = build_model(cfg)
    warmup = max(int(round(warmup_ratio * steps)), 1)
    sched = core.warmup_poly_decay(lr, steps, warmup)
    tc = TrainConfig(optimizer=optimizer, learning_rate=lr,
                     weight_decay=weight_decay, seed=seed)
    tr = Trainer(model, tc, schedule=sched, log_every=max(steps // 4, 1),
                 log_fn=lambda s: None)

    src = SyntheticLM(cfg.vocab_size, seed=1)
    rngs = (np.random.default_rng((seed, i)) for i in itertools.count())
    data = (make_batch(cfg, next(rngs), batch, seq, src) for _ in itertools.count())
    t0 = time.perf_counter()
    hist = tr.fit(data, steps)
    wall = time.perf_counter() - t0

    # held-out eval (fresh seed stream)
    from repro.train.step import make_loss_fn

    loss_fn = jax.jit(make_loss_fn(model))
    eval_rng = np.random.default_rng(10_000 + seed)
    losses, accs = [], []
    for _ in range(eval_batches):
        b = jax.tree.map(jnp.asarray, make_batch(cfg, eval_rng, batch, seq, src))
        l, m = loss_fn(tr.state.params, b)
        losses.append(float(l))
        accs.append(float(m["accuracy"]))
    return {
        "train_loss": hist[-1]["loss/total"],
        "eval_loss": float(np.mean(losses)),
        "eval_acc": float(np.mean(accs)),
        "steps": steps,
        "wall_s": wall,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
