"""Static vs continuous batching under staggered arrivals.

    PYTHONPATH=src python benchmarks/serve_bench.py [--requests 12 --slots 4]

Two servers over the same smoke model and the same Poisson-arrival workload
(mixed prompt lengths and generation budgets):

* static  — a fixed-shape batch server: collects whatever has arrived (up to
  the slot count), pads the batch to a fixed (slots, max_prompt) shape, and
  blocks until the slowest request in the batch finishes before admitting
  more work (the pre-PR ``Engine`` driven the only way it can be);
* continuous — the slot-pool ``ContinuousEngine``: admits work between
  single-token steps, so a finished slot is refilled immediately.

Both engines are jit-warmed on every shape they will see before the timed
run, so the comparison is steady-state step cost, not compile time.  The
script also checks greedy token-for-token equivalence between the two
engines on a shared same-length request set (see the determinism caveat in
``repro/serve/continuous.py`` — row-independent families match exactly).
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    Engine,
    FCFSScheduler,
    Request,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    serving_stats,
)


def make_workload(n: int, seed: int, prompt_lens=(8, 12, 16),
                  max_new=(4, 8, 12, 32)) -> List[ServeRequest]:
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            prompt=rng.integers(0, 256, size=int(rng.choice(prompt_lens)))
            .astype(np.int32),
            max_new_tokens=int(rng.choice(max_new)),
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# static-batch server simulation
# ---------------------------------------------------------------------------

def run_static(model, params, workload: List[ServeRequest], *, slots: int,
               max_len: int) -> List[ServeRequest]:
    """Fixed-shape batch server: every batch is exactly (slots, max_prompt)
    tokens (filler rows + prompt padding keep the jit cache at one entry),
    decoded to the batch's max max_new_tokens, results sliced per request."""
    eng = Engine(model, params, max_len=max_len)
    pad_s = max(len(r.prompt) for r in workload)

    def to_static(r: ServeRequest) -> Request:
        p = np.zeros(pad_s, np.int32)
        p[: len(r.prompt)] = r.prompt
        return Request(p, max_new_tokens=r.max_new_tokens,
                       temperature=r.temperature)

    # warm the (slots, pad_s) prefill/decode jit once, untimed
    eng.generate_batch([to_static(workload[0]) for _ in range(slots)])

    pending = sorted(workload, key=lambda r: (r.arrival_s, r.rid))
    clock = 0.0
    done: List[ServeRequest] = []
    while pending:
        arrived = [r for r in pending if r.arrival_s <= clock]
        if not arrived:
            clock = pending[0].arrival_s  # idle: jump to next arrival
            continue
        batch = arrived[:slots]
        filler = [batch[0]] * (slots - len(batch))  # fixed batch shape
        t0 = time.perf_counter()
        out = eng.generate_batch([to_static(r) for r in batch + filler])
        clock += time.perf_counter() - t0
        for req, res in zip(batch, out):
            req.out_tokens = list(map(int, res.out_tokens))
            req.first_token_s = clock  # static batch: nothing streams early
            req.finish_s = clock
            pending.remove(req)
            done.append(req)
    return done


# ---------------------------------------------------------------------------
# continuous server
# ---------------------------------------------------------------------------

def run_continuous(model, params, workload: List[ServeRequest], *, slots: int,
                   max_len: int, warm_lens) -> List[ServeRequest]:
    eng = ContinuousEngine(model, params, n_slots=slots, max_len=max_len,
                           scheduler=FCFSScheduler())
    # warm every prompt-length prefill + the decode step, untimed
    warm = [ServeRequest(np.zeros(s, np.int32), max_new_tokens=2)
            for s in warm_lens]
    eng.generate(warm)
    assert eng.pool.n_free == slots, "warmup drained the pool"
    return eng.generate(workload)


# ---------------------------------------------------------------------------
# greedy equivalence on a shared same-length request set
# ---------------------------------------------------------------------------

def check_equivalence(model, params, *, n: int = 6, prompt_len: int = 12,
                      slots: int = 3, max_len: int = 64, seed: int = 7) -> bool:
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, size=prompt_len).astype(np.int32)
               for _ in range(n)]
    new = [int(x) for x in rng.integers(4, 12, size=n)]
    eng = Engine(model, params, max_len=max_len)
    ref = eng.generate_batch(
        [Request(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    ce = ContinuousEngine(model, params, n_slots=slots, max_len=max_len)
    out = ce.generate(
        [ServeRequest(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    return all(
        np.array_equal(np.asarray(r.out_tokens), np.asarray(s.out_tokens))
        for r, s in zip(out, ref)
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=25.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"model={cfg.name} ({model.param_count()/1e6:.2f}M) "
          f"requests={args.requests} slots={args.slots} "
          f"rate={args.arrival_rate}/s")

    workload = make_workload(args.requests, args.seed)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in workload) + 8
    arrivals = poisson_arrivals(len(workload), args.arrival_rate,
                                seed=args.seed)

    def fresh():
        ws = make_workload(args.requests, args.seed)
        for i, r in enumerate(ws):
            r.rid = i
        return assign_arrivals(ws, arrivals)

    static_done = run_static(model, params, fresh(), slots=args.slots,
                             max_len=max_len)
    cont_done = run_continuous(
        model, params, fresh(), slots=args.slots, max_len=max_len,
        warm_lens=sorted({len(r.prompt) for r in workload}),
    )

    s_stats = serving_stats(static_done)
    c_stats = serving_stats(cont_done)
    print(f"\n{'':12s} {'tok/s':>8s} {'p50 lat':>9s} {'p99 lat':>9s} "
          f"{'p50 ttft':>9s}")
    for name, st in (("static", s_stats), ("continuous", c_stats)):
        print(f"{name:12s} {st['tokens_per_s']:8.2f} "
              f"{st['latency_p50_s']:8.3f}s {st['latency_p99_s']:8.3f}s "
              f"{st['ttft_p50_s']:8.3f}s")

    speedup = c_stats["tokens_per_s"] / max(s_stats["tokens_per_s"], 1e-9)
    print(f"\ncontinuous/static tokens/s: {speedup:.2f}x "
          f"-> {'PASS' if speedup > 1.0 else 'FAIL'} (want > 1.0)")

    eq = check_equivalence(model, params)
    print(f"greedy continuous == static (shared request set): "
          f"{'PASS' if eq else 'FAIL'}")
    return 0 if (speedup > 1.0 and eq) else 1


if __name__ == "__main__":
    raise SystemExit(main())
