"""Static vs continuous batching under staggered arrivals — plus the
serving reliability scenarios (overload shedding, fault injection).

    PYTHONPATH=src python benchmarks/serve_bench.py [--requests 12 --slots 4]
    PYTHONPATH=src python benchmarks/serve_bench.py --scenarios [--fast]

``--scenarios`` runs the reliability suite (also ``benchmarks.run`` key
``serve``) and writes a provenance-stamped ``BENCH_serve.json``:

* **capacity** — closed-batch run with no overload: the goodput and
  per-request service-time reference everything else is judged against;
* **overload** — a Poisson arrival stream at 2× the measured capacity
  rate against a bounded queue + per-request deadlines: admission control
  must shed explicitly (never queue silently), keep admitted-request p99
  within the structural SLO bound (deadline + 3× the capacity run's worst
  service time — machine-relative, so the claim travels), and hold
  goodput ≥ 80% of the capacity run;
* **faults** — deterministic injector scenario (sampling NaN → retry,
  slot corruption → quarantine + retry, persistent NaN → retry budget
  exhausted → FAILED, decode stall → degraded mode): every submitted
  request must end in exactly one terminal state, and a replay after
  ``injector.reset()`` must reproduce the terminal-state counts exactly.

Two servers over the same smoke model and the same Poisson-arrival workload
(mixed prompt lengths and generation budgets):

* static  — a fixed-shape batch server: collects whatever has arrived (up to
  the slot count), pads the batch to a fixed (slots, max_prompt) shape, and
  blocks until the slowest request in the batch finishes before admitting
  more work (the pre-PR ``Engine`` driven the only way it can be);
* continuous — the slot-pool ``ContinuousEngine``: admits work between
  single-token steps, so a finished slot is refilled immediately.

Both engines are jit-warmed on every shape they will see before the timed
run, so the comparison is steady-state step cost, not compile time.  The
script also checks greedy token-for-token equivalence between the two
engines on a shared same-length request set (see the determinism caveat in
``repro/serve/continuous.py`` — row-independent families match exactly).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    Engine,
    FCFSScheduler,
    Request,
    RequestStatus,
    ServeFaultInjector,
    ServeFaultSpec,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    serving_stats,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_serve.json"


def make_workload(n: int, seed: int, prompt_lens=(8, 12, 16),
                  max_new=(4, 8, 12, 32)) -> List[ServeRequest]:
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            prompt=rng.integers(0, 256, size=int(rng.choice(prompt_lens)))
            .astype(np.int32),
            max_new_tokens=int(rng.choice(max_new)),
        )
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# static-batch server simulation
# ---------------------------------------------------------------------------

def run_static(model, params, workload: List[ServeRequest], *, slots: int,
               max_len: int) -> List[ServeRequest]:
    """Fixed-shape batch server: every batch is exactly (slots, max_prompt)
    tokens (filler rows + prompt padding keep the jit cache at one entry),
    decoded to the batch's max max_new_tokens, results sliced per request."""
    eng = Engine(model, params, max_len=max_len)
    pad_s = max(len(r.prompt) for r in workload)

    def to_static(r: ServeRequest) -> Request:
        p = np.zeros(pad_s, np.int32)
        p[: len(r.prompt)] = r.prompt
        return Request(p, max_new_tokens=r.max_new_tokens,
                       temperature=r.temperature)

    # warm the (slots, pad_s) prefill/decode jit once, untimed
    eng.generate_batch([to_static(workload[0]) for _ in range(slots)])

    pending = sorted(workload, key=lambda r: (r.arrival_s, r.rid))
    clock = 0.0
    done: List[ServeRequest] = []
    while pending:
        arrived = [r for r in pending if r.arrival_s <= clock]
        if not arrived:
            clock = pending[0].arrival_s  # idle: jump to next arrival
            continue
        batch = arrived[:slots]
        filler = [batch[0]] * (slots - len(batch))  # fixed batch shape
        t0 = time.perf_counter()
        out = eng.generate_batch([to_static(r) for r in batch + filler])
        clock += time.perf_counter() - t0
        for req, res in zip(batch, out):
            req.out_tokens = list(map(int, res.out_tokens))
            req.first_token_s = clock  # static batch: nothing streams early
            req.finish_s = clock
            pending.remove(req)
            done.append(req)
    return done


# ---------------------------------------------------------------------------
# continuous server
# ---------------------------------------------------------------------------

def run_continuous(model, params, workload: List[ServeRequest], *, slots: int,
                   max_len: int, warm_lens) -> List[ServeRequest]:
    eng = ContinuousEngine(model, params, n_slots=slots, max_len=max_len,
                           scheduler=FCFSScheduler())
    # warm every prompt-length prefill + the decode step, untimed
    warm = [ServeRequest(np.zeros(s, np.int32), max_new_tokens=2)
            for s in warm_lens]
    eng.generate(warm)
    assert eng.pool.n_free == slots, "warmup drained the pool"
    return eng.generate(workload)


# ---------------------------------------------------------------------------
# greedy equivalence on a shared same-length request set
# ---------------------------------------------------------------------------

def check_equivalence(model, params, *, n: int = 6, prompt_len: int = 12,
                      slots: int = 3, max_len: int = 64, seed: int = 7) -> bool:
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, size=prompt_len).astype(np.int32)
               for _ in range(n)]
    new = [int(x) for x in rng.integers(4, 12, size=n)]
    eng = Engine(model, params, max_len=max_len)
    ref = eng.generate_batch(
        [Request(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    ce = ContinuousEngine(model, params, n_slots=slots, max_len=max_len)
    out = ce.generate(
        [ServeRequest(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    return all(
        np.array_equal(np.asarray(r.out_tokens), np.asarray(s.out_tokens))
        for r, s in zip(out, ref)
    )


# ---------------------------------------------------------------------------
# reliability scenarios: capacity baseline, 2x overload, fault injection
# ---------------------------------------------------------------------------

def _scenario_workload(n: int, seed: int) -> List[ServeRequest]:
    """Greedy (temperature-0) mixed workload with explicit rids, so every
    replay is token- and fault-deterministic."""
    reqs = make_workload(n, seed)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def _status_counts(reqs: List[ServeRequest]) -> Dict[str, int]:
    return {
        s.value: sum(1 for r in reqs if r.status is s)
        for s in (RequestStatus.COMPLETED, RequestStatus.SHED,
                  RequestStatus.TIMED_OUT, RequestStatus.FAILED)
    }


def run_scenarios(fast: bool = False, *, seed: int = 0,
                  out: pathlib.Path = OUT_JSON) -> Dict:
    """The reliability suite behind ``--scenarios`` / the ``serve`` bench
    key.  Returns the report dict written to ``BENCH_serve.json``."""
    try:
        from benchmarks.common import provenance_header
    except ModuleNotFoundError:  # run as a script
        import sys

        sys.path.insert(0, str(ROOT))
        from benchmarks.common import provenance_header

    # under 2x overload the backlog at the end of arrivals is ~n/2 - slots
    # requests: n must clear 2 * (max_queue + slots) by a margin or the
    # overload phase ends before the queue bound ever binds
    n = 24 if fast else 48
    slots = 4
    max_len = 64
    cfg = smoke_config("smollm-360m")
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))

    # one engine for every phase: slot churn never recompiles, so reusing
    # it keeps the suite's wall time at one jit warmup.  Phases swap the
    # scheduler and reliability knobs; generate() drains the pool between.
    eng = ContinuousEngine(model, params, n_slots=slots, max_len=max_len,
                           seed=seed)
    warm_lens = sorted({len(r.prompt) for r in _scenario_workload(n, seed)})
    eng.generate([ServeRequest(np.zeros(s, np.int32), max_new_tokens=2)
                  for s in warm_lens])

    # --- phase 1: capacity (closed batch, nothing sheds) -----------------
    cap_reqs = _scenario_workload(n, seed)
    eng.scheduler = FCFSScheduler()
    eng.generate(cap_reqs)
    cap = serving_stats(cap_reqs)
    assert cap["completed"] == n, "capacity run must complete everything"
    # worst per-request service time (admission -> finish) while saturated:
    # the machine-relative unit the overload SLO bound is built from
    service_max_s = max(r.finish_s - r.admitted_s for r in cap_reqs)
    cap_req_rate = cap["completed"] / cap["wall_s"]

    # --- phase 2: 2x sustained overload + admission control --------------
    # bounds are multiples of the measured service time, so the scenario is
    # machine-relative: arrivals outpace service 2:1 whatever the hardware,
    # the queue must overflow, and shedding must engage — silently queueing
    # everything would blow the deadline sweep instead
    deadline_s = 2.0 * service_max_s
    slo_bound_s = deadline_s + 3.0 * service_max_s
    over_reqs = _scenario_workload(n, seed)
    assign_arrivals(
        over_reqs, poisson_arrivals(n, 2.0 * cap_req_rate, seed=seed))
    for r in over_reqs:
        r.deadline_s = deadline_s
    eng.scheduler = FCFSScheduler(max_queue=slots)
    eng.generate(over_reqs)
    over = serving_stats(over_reqs)
    admitted = [r for r in over_reqs if r.status is RequestStatus.COMPLETED]
    over_p99 = (float(np.percentile([r.latency_s for r in admitted], 99))
                if admitted else float("inf"))
    goodput_ratio = over.get("tokens_per_s", 0.0) / cap["tokens_per_s"]
    # the suite's own workload: sheds are expected, silent queueing is not
    terminal_ok_over = sum(_status_counts(over_reqs).values()) == n

    # --- phase 3: deterministic fault injection + replay -----------------
    specs = [
        ServeFaultSpec("sample_nan", at=1),                  # retry succeeds
        ServeFaultSpec("slot_corrupt", at=2),                # quarantine+retry
        ServeFaultSpec("sample_nan", at=3, once=False),      # budget exhausts
        ServeFaultSpec("decode_stall", at=5, stall_s=0.08),  # watchdog trips
    ]
    injector = ServeFaultInjector(specs)
    eng.scheduler = FCFSScheduler()
    eng.faults = injector
    eng.stall_slo_s = 0.04
    counts_by_run = []
    for _ in range(2):  # second run replays the identical fault sequence
        injector.reset()
        fault_reqs = _scenario_workload(n, seed)
        eng.generate(fault_reqs)
        counts_by_run.append(_status_counts(fault_reqs))
    eng.faults = None
    eng.stall_slo_s = None
    fault_counts = counts_by_run[0]
    fires = injector.fire_counts()

    claims = {
        "overload_p99_within_slo": {
            "p99_s": over_p99, "slo_bound_s": slo_bound_s,
            "holds": over_p99 <= slo_bound_s,
        },
        "overload_goodput_ge_80pct_capacity": {
            "goodput_ratio": goodput_ratio,
            "holds": goodput_ratio >= 0.8,
        },
        "overload_sheds_explicitly": {
            "shed": over["shed"] + over["timed_out"],
            "holds": over["shed"] + over["timed_out"] > 0,
        },
        "every_request_terminal": {
            "holds": (terminal_ok_over
                      and sum(fault_counts.values()) == n
                      and sum(_status_counts(cap_reqs).values()) == n),
        },
        "fault_counts_replay_deterministic": {
            "counts": fault_counts,
            "holds": (counts_by_run[0] == counts_by_run[1]
                      and fault_counts["failed"] == 1
                      and fault_counts["completed"] == n - 1),
        },
    }
    report = {
        "provenance": provenance_header(time.time()),
        "protocol": {
            "requests": n, "slots": slots, "seed": seed, "fast": fast,
            "deadline_s": deadline_s, "service_max_s": service_max_s,
            "overload_rate": 2.0 * cap_req_rate,
            "fault_specs": [f"{s.kind}@{s.at}" + ("" if s.once else ":persist")
                            for s in specs],
        },
        "capacity": cap,
        "overload": {**over, "admitted_p99_s": over_p99,
                     "goodput_ratio": goodput_ratio},
        "faults": {"counts": fault_counts, "fires": fires,
                   "replay_counts": counts_by_run[1]},
        "claims": claims,
    }
    out.write_text(json.dumps(report, indent=2))
    return report


def run(fast: bool = False) -> List[str]:
    """``benchmarks.run`` entry point: CSV rows + ``BENCH_serve.json``."""
    try:
        from benchmarks.common import csv_row
    except ModuleNotFoundError:
        import sys

        sys.path.insert(0, str(ROOT))
        from benchmarks.common import csv_row

    rep = run_scenarios(fast=fast)
    cap, over, claims = rep["capacity"], rep["overload"], rep["claims"]
    rows = [
        csv_row("serve/capacity", 0.0,
                f"tok_per_s={cap['tokens_per_s']:.1f};"
                f"completed={cap['completed']}"),
        csv_row("serve/overload_2x", 0.0,
                f"tok_per_s={over['tokens_per_s']:.1f};"
                f"completed={over['completed']};shed={over['shed']};"
                f"p99_s={over['admitted_p99_s']:.3f}"),
        csv_row("serve/faults", 0.0,
                ";".join(f"{k}={v}" for k, v in
                         rep["faults"]["counts"].items())),
    ]
    for name, c in claims.items():
        rows.append(csv_row(f"serve/claim_{name}", 0.0, f"holds={c['holds']}"))
    if not all(c["holds"] for c in claims.values()):
        failed = [k for k, c in claims.items() if not c["holds"]]
        raise RuntimeError(f"serve reliability claims failed: {failed}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=25.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", action="store_true",
                    help="run the reliability suite (overload + faults) "
                         "instead of the static-vs-continuous comparison")
    ap.add_argument("--fast", action="store_true",
                    help="smaller workload for the scenario suite")
    args = ap.parse_args()

    if args.scenarios:
        for row in run(fast=args.fast):
            print(row)
        print(f"report: {OUT_JSON}")
        return 0

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"model={cfg.name} ({model.param_count()/1e6:.2f}M) "
          f"requests={args.requests} slots={args.slots} "
          f"rate={args.arrival_rate}/s")

    workload = make_workload(args.requests, args.seed)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in workload) + 8
    arrivals = poisson_arrivals(len(workload), args.arrival_rate,
                                seed=args.seed)

    def fresh():
        ws = make_workload(args.requests, args.seed)
        for i, r in enumerate(ws):
            r.rid = i
        return assign_arrivals(ws, arrivals)

    static_done = run_static(model, params, fresh(), slots=args.slots,
                             max_len=max_len)
    cont_done = run_continuous(
        model, params, fresh(), slots=args.slots, max_len=max_len,
        warm_lens=sorted({len(r.prompt) for r in workload}),
    )

    s_stats = serving_stats(static_done)
    c_stats = serving_stats(cont_done)
    print(f"\n{'':12s} {'tok/s':>8s} {'p50 lat':>9s} {'p99 lat':>9s} "
          f"{'p50 ttft':>9s}")
    for name, st in (("static", s_stats), ("continuous", c_stats)):
        print(f"{name:12s} {st['tokens_per_s']:8.2f} "
              f"{st['latency_p50_s']:8.3f}s {st['latency_p99_s']:8.3f}s "
              f"{st['ttft_p50_s']:8.3f}s")

    speedup = c_stats["tokens_per_s"] / max(s_stats["tokens_per_s"], 1e-9)
    print(f"\ncontinuous/static tokens/s: {speedup:.2f}x "
          f"-> {'PASS' if speedup > 1.0 else 'FAIL'} (want > 1.0)")

    eq = check_equivalence(model, params)
    print(f"greedy continuous == static (shared request set): "
          f"{'PASS' if eq else 'FAIL'}")
    return 0 if (speedup > 1.0 and eq) else 1


if __name__ == "__main__":
    raise SystemExit(main())
