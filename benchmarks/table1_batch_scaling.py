"""Paper Table 1/4 analogue: batch-size scaling at fixed epochs, untuned LAMB.

Protocol (CPU-scaled): fixed token budget; batch grows 16→64 so steps shrink
4×; LAMB's LR/warmup follow the paper's untuned recipe (sqrt scaling +
linear-epoch warmup) — no per-batch tuning.  AdamW runs the same protocol as
the reference point.

Claim validated (CPU regime note): at paper scale training saturates and
LAMB's large-batch quality matches small-batch outright; at this compute
scale nothing saturates, so the claim is validated *comparatively* — LAMB's
large-batch degradation must be smaller than AdamW's (LAMB "enables" the
large batch), mirroring Table 1 vs the AdamW-stops-scaling finding (§4.1).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import bert_cpu, csv_row, fixed_epoch_steps
from benchmarks.protocol import recipe, train_once

SEQ = 32
BASE_BATCH = 16
TOKENS = BASE_BATCH * SEQ * 600
OPTIMIZERS = ("lamb", "adamw")


def _cfg():
    return bert_cpu().replace(n_layers=2, d_model=128, d_ff=256, vocab_size=512)


def run(batches=(16, 64)) -> List[str]:
    cfg = _cfg()
    rows, results = [], {}
    for opt in OPTIMIZERS:
        for b in batches:
            steps = fixed_epoch_steps(TOKENS, b, SEQ)
            r = recipe(opt, b, base_batch=BASE_BATCH)
            t0 = time.perf_counter()
            out = train_once(cfg, optimizer=opt, batch=b, seq=SEQ,
                             steps=steps, lr=r["lr"],
                             warmup_ratio=r["warmup_ratio"])
            us = (time.perf_counter() - t0) / max(steps, 1) * 1e6
            results[(opt, b)] = out
            rows.append(csv_row(
                f"table1/{opt}_batch{b}", us,
                f"steps={steps};lr={r['lr']:.2e};"
                f"eval_loss={out['eval_loss']:.4f};"
                f"eval_acc={out['eval_acc']:.4f}",
            ))
    # Paper App. H: "validation loss is not reliable ... we use accuracy" —
    # the claims therefore compare eval ACCURACY degradation.
    small, big = batches[0], batches[-1]
    deg = {
        opt: results[(opt, small)]["eval_acc"] - results[(opt, big)]["eval_acc"]
        for opt in OPTIMIZERS
    }
    rows.append(csv_row(
        "table1/claim_lamb_scales_better_than_adamw", 0.0,
        f"lamb_acc_degradation={deg['lamb']:.4f};"
        f"adamw_acc_degradation={deg['adamw']:.4f};"
        f"holds={deg['lamb'] < deg['adamw']}",
    ))
    rows.append(csv_row(
        "table1/claim_lamb_best_at_large_batch", 0.0,
        f"lamb_acc={results[('lamb', big)]['eval_acc']:.4f};"
        f"adamw_acc={results[('adamw', big)]['eval_acc']:.4f};"
        f"holds={results[('lamb', big)]['eval_acc'] >= results[('adamw', big)]['eval_acc']}",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
