"""Sharding benchmark: per-device parameter+optimizer memory vs mesh size.

The paper's 76-minute run exists because data-parallel scale-out is
(nearly) free in per-device state: under FSDP each of N ranks holds 1/N of
the params and of LAMB's two moment buffers.  This benchmark measures that
for real on 8 virtual CPU devices — live per-device state bytes and the
compiled step's per-device argument footprint for mesh sizes 1/2/4/8 —
plus steady-state step wall time.  Results land in ``BENCH_sharding.json``;
the claim (acceptance): per-device param+optimizer bytes on ``data=8`` are
≤ 1/4 of the unsharded step's.

Like the dry-run, the multi-device half must set XLA_FLAGS before jax
initializes, so ``run()`` re-executes this file as a ``--child``
subprocess and parses its JSON.

    PYTHONPATH=src python benchmarks/sharding_bench.py
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time
from typing import List

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_sharding.json"
MESH_SIZES = (1, 2, 4, 8)
CLAIM_RATIO = 4.0  # data=8 FSDP state must be ≤ 1/4 of unsharded


def _child() -> dict:
    """Runs under --xla_force_host_platform_device_count=8 (see run())."""
    from repro.configs import smoke_config
    from repro.configs.base import TrainConfig
    from repro.data import DataPipeline
    from repro.launch.mesh import make_mesh_from_spec
    from repro.models import build_model
    from repro.sharding import per_device_state_bytes
    from repro.train import Trainer

    cfg = smoke_config("bert-large")
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    batch, seq, iters = 16, 64, 5

    results = []
    for n in MESH_SIZES:
        mesh = make_mesh_from_spec(f"data={n},model=1") if n > 1 else None
        model = build_model(cfg)
        tr = Trainer(model, tc, mesh=mesh, log_every=10**6,
                     log_fn=lambda s: None)
        tr.init()
        data = DataPipeline(cfg, batch, seq, seed=0, mesh=mesh)
        state_bytes = per_device_state_bytes(
            tr.state.params
        ) + per_device_state_bytes(tr.state.opt_state)
        entry = {
            "mesh": f"data={n}",
            "devices": n,
            "state_bytes_per_device": state_bytes,
        }
        try:
            b0 = tr._place_batch(next(data))
            ma = tr._step_fn.lower(tr.state, b0).compile().memory_analysis()
            entry["compiled_argument_bytes"] = int(ma.argument_size_in_bytes)
            entry["compiled_temp_bytes"] = int(ma.temp_size_in_bytes)
        except Exception as e:  # memory_analysis is backend-dependent
            entry["compiled_error"] = f"{type(e).__name__}: {e}"
        # steady-state step time (first fit() call compiled the step)
        tr.fit(data, 1)
        t0 = time.perf_counter()
        tr.fit(data, iters)
        entry["step_ms"] = (time.perf_counter() - t0) / iters * 1e3
        results.append(entry)

    base = results[0]["state_bytes_per_device"]
    fsdp8 = results[-1]["state_bytes_per_device"]
    return {
        "arch": cfg.name,
        "batch": batch,
        "seq": seq,
        "results": results,
        "claim_ratio": CLAIM_RATIO,
        "state_ratio_8x": base / max(fsdp8, 1),
        "holds": bool(fsdp8 * CLAIM_RATIO <= base),
    }


def run() -> List[str]:
    try:
        from benchmarks.common import csv_row, provenance_header
    except ModuleNotFoundError:  # run as a script
        sys.path.insert(0, str(ROOT))
        from benchmarks.common import csv_row, provenance_header

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(pathlib.Path(__file__).resolve()), "--child"],
        capture_output=True, text=True, timeout=1800, cwd=ROOT, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"sharding_bench child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.splitlines()[-1])
    # the header describes the *parent* environment; the child's virtual
    # 8-device mesh is already recorded in the per-mesh results
    report = {"provenance": provenance_header(time.time()), **report}
    OUT_JSON.write_text(json.dumps(report, indent=2))

    rows = []
    for r in report["results"]:
        rows.append(csv_row(
            f"sharding/step_{r['mesh']}", r["step_ms"] * 1e3,
            f"state_bytes_per_device={r['state_bytes_per_device']};"
            f"compiled_argument_bytes={r.get('compiled_argument_bytes', 0)}",
        ))
    rows.append(csv_row(
        "sharding/fsdp8_state_under_quarter", 0.0,
        f"ratio={report['state_ratio_8x']:.2f}x;"
        f"holds={int(report['holds'])}",
    ))
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        print(json.dumps(_child()))
    else:
        print("\n".join(run()))
