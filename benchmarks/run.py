"""Benchmark harness entry point — one module per paper table.

    PYTHONPATH=src python -m benchmarks.run [--only table1,table4] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (claims carry a ``holds=`` flag).
The sweep also feeds an in-memory event log (one ``bench_result`` event per
suite) and folds it — together with every ``BENCH_*.json`` the suites wrote —
into a unified ``RUN_REPORT.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import importlib.util
import inspect
import pathlib
import sys
import time

if importlib.util.find_spec("benchmarks") is None:
    # run as a script (`python benchmarks/run.py`): put the repo root on the
    # path so the `benchmarks.*` suite imports below resolve
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

ROOT = pathlib.Path(__file__).resolve().parent.parent

SUITES = [
    ("table4", "benchmarks.table4_recipe_values", "Tables 4-5 recipe values (exact)"),
    ("roofline", "benchmarks.roofline_report", "§Roofline report from dry-run JSONL"),
    ("opt_step", "benchmarks.opt_step_bench", "fused vs unfused LAMB step"),
    ("attention", "benchmarks.attention_bench", "dense vs flash attention fwd/bwd"),
    ("train_step", "benchmarks.train_step_bench", "dense vs fused-CE MLM head step"),
    ("sharding", "benchmarks.sharding_bench", "per-device state memory vs mesh size"),
    ("scaling", "benchmarks.scaling_bench", "accum × precision × fused-LAMB scaling"),
    ("table1", "benchmarks.table1_batch_scaling", "Table 1/4 batch scaling"),
    ("table2", "benchmarks.table2_lamb_vs_lars", "Table 2 LAMB vs LARS"),
    ("mixed_batch", "benchmarks.mixed_batch_bench", "§4.1 mixed-batch + re-warmup"),
    ("table3", "benchmarks.table3_optimizer_comparison", "Table 3 tuned baselines"),
    ("convergence", "benchmarks.convergence_bench",
     "steps-to-target vs global batch (fused stack, LAMB/LANS/tuned AdamW)"),
    ("serve", "benchmarks.serve_bench",
     "serving reliability: 2x-overload shedding + deterministic faults"),
]

# convergence stays in FAST via its own --fast tier (suites whose run()
# takes a ``fast`` kwarg get it forwarded below)
FAST = {"table4", "roofline", "opt_step", "attention", "train_step", "sharding",
        "scaling", "convergence", "serve"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated suite keys")
    ap.add_argument("--fast", action="store_true",
                    help="skip the training suites (CPU-minutes each)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from repro.telemetry import EventLog, RunReport, run_provenance

    log = EventLog.memory()
    log.emit("run_start", mode="bench", provenance=run_provenance())

    print("name,us_per_call,derived")
    failures = 0
    for key, module, desc in SUITES:
        if only is not None and key not in only:
            continue
        if args.fast and key not in FAST:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(module, fromlist=["run"])
            kwargs = {}
            if args.fast and "fast" in inspect.signature(mod.run).parameters:
                kwargs["fast"] = True
            rows = list(mod.run(**kwargs))
            for row in rows:
                print(row, flush=True)
            log.emit("bench_result", name=key, desc=desc, ok=True,
                     rows=len(rows), wall_s=time.perf_counter() - t0)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{key}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            log.emit("bench_result", name=key, desc=desc, ok=False,
                     error=f"{type(e).__name__}: {e}",
                     wall_s=time.perf_counter() - t0)
        print(f"# {key}: {desc} [{time.perf_counter()-t0:.1f}s]", file=sys.stderr)

    log.emit("run_end", status="fail" if failures else "ok",
             failures=failures)
    report_path = ROOT / "RUN_REPORT.json"
    RunReport.from_events(log, bench_dir=ROOT).write(report_path)
    print(f"# report: {report_path}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
