"""One protocol for every claims benchmark: the fused production path.

The paper's claims are about *training runs*, so every bench that trains —
the Table 1/2/3 analogues and the convergence harness — must exercise the
same code a real run uses: flash attention + the fused chunked-vocab CE head
(model side), and the fused LAMB update / gradient accumulation / bf16
compute (TrainConfig side).  Benching a legacy dense path would validate
claims about code nobody ships.

This module is that single path.  It owns:

* ``train_once`` — train on the deterministic synthetic corpus through a
  ``Trainer`` built from :func:`make_train_config`, returning final
  train/eval metrics **plus the logged loss trajectory** (what the
  convergence bench reduces to steps-to-target).
* ``train_stages`` — the same through ``Trainer.fit_stages`` for the §4.1
  two-stage seq128→seq512 mixed-batch recipe (stage-2 re-warm-up).
* the untuned recipe (sqrt LR scaling + linear-epoch warmup, §4/Table 1)
  and the grid-tuned AdamW baseline protocol (Nado et al.: the baseline is
  granted the per-batch tuning the LAMB recipe is denied).
* ``steps_to_target`` — first logged step at or below a loss target.

``benchmarks.common.train_once`` forwards here, so the three table benches
and the convergence bench share one implementation by construction.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.configs.base import TrainConfig
from repro.core.mixed_batch import Stage
from repro.data import make_batch
from repro.data.synthetic import SyntheticLM
from repro.models import build_model
from repro.train import Trainer

LOSS_KEY = "loss/total"

# Untuned-recipe base LRs at the base batch (§4.1 style: one number per
# optimizer, then sqrt-scaled — never re-tuned per batch size).
UNTUNED_BASE_LR = {"lamb": 6e-3, "lans": 6e-3, "adamw": 1e-3, "lars": 0.3}

# Nado et al. baseline protocol: the AdamW peak LR is grid-searched at every
# batch size (tuned baseline vs untuned LAMB/LANS recipe).
ADAMW_TUNING_GRID: Tuple[float, ...] = (3e-4, 1e-3, 3e-3)

# Model-side production kernels every protocol run goes through.
FUSED_STACK = dict(use_flash_kernel=True, use_fused_ce_head=True)


def fused_model_config(cfg):
    """Force a model config onto the production kernels (flash + fused CE)."""
    return cfg.replace(**FUSED_STACK)


def make_train_config(
    optimizer: str,
    lr: float,
    *,
    weight_decay: float = 0.01,
    seed: int = 0,
    accum_steps: int = 1,
    precision: str = "fp32",
    fused: bool = True,
) -> TrainConfig:
    """The protocol's TrainConfig: fused LAMB on whenever it applies.

    ``use_fused_lamb`` only has a fused implementation for ``lamb`` (LANS and
    the baselines ride the transform chain), so it is gated on the optimizer
    rather than asserted.
    """
    return TrainConfig(
        optimizer=optimizer,
        learning_rate=lr,
        weight_decay=weight_decay,
        seed=seed,
        accum_steps=accum_steps,
        precision=precision,
        use_fused_lamb=bool(fused and optimizer == "lamb"),
    )


def recipe(
    optimizer: str,
    batch: int,
    *,
    base_batch: int,
    base_lr: Optional[float] = None,
    base_warmup_ratio: float = 1.0 / 40.0,
) -> Dict[str, float]:
    """Untuned large-batch recipe: sqrt-scaled LR + linear-epoch warmup."""
    base = UNTUNED_BASE_LR[optimizer] if base_lr is None else base_lr
    return {
        "lr": core.sqrt_scaled_lr(base, base_batch, batch),
        "warmup_ratio": core.linear_epoch_warmup_ratio(
            base_warmup_ratio, base_batch, batch
        ),
    }


def steps_to_target(
    history: Iterable[Dict[str, float]], target: float, key: str = LOSS_KEY
) -> Optional[int]:
    """First logged step whose loss is ≤ ``target`` (None if never reached).

    Operates on logged rows, so resolution is the Trainer's ``log_every``;
    the convergence bench logs every step at CPU scale.
    """
    for row in history:
        if float(row.get(key, float("inf"))) <= target:
            return int(row["step"])
    return None


def synthetic_stream(cfg, batch: int, seq: int, *, seed: int = 0,
                     corpus_seed: int = 1):
    """Deterministic synthetic-MLM batch iterator (the shared bench corpus)."""
    src = SyntheticLM(cfg.vocab_size, seed=corpus_seed)
    rngs = (np.random.default_rng((seed, i)) for i in itertools.count())
    it = (make_batch(cfg, next(rngs), batch, seq, src) for _ in itertools.count())
    return it, src


def _evaluate(model, params, src, *, batch: int, seq: int, seed: int,
              eval_batches: int) -> Tuple[float, float]:
    """Held-out eval on a fresh seed stream; returns (loss, accuracy)."""
    from repro.train.step import make_loss_fn

    loss_fn = jax.jit(make_loss_fn(model))
    eval_rng = np.random.default_rng(10_000 + seed)
    losses, accs = [], []
    for _ in range(eval_batches):
        b = jax.tree.map(
            jnp.asarray, make_batch(model.cfg, eval_rng, batch, seq, src)
        )
        l, m = loss_fn(params, b)
        losses.append(float(l))
        accs.append(float(m["accuracy"]))
    return float(np.mean(losses)), float(np.mean(accs))


def _trajectory(history: Sequence[Dict[str, float]]) -> List[Dict[str, float]]:
    rows = []
    for r in history:
        row = {"step": int(r["step"]), "loss": float(r.get(LOSS_KEY, float("nan")))}
        if "stage" in r:
            row["stage"] = int(r["stage"])
        rows.append(row)
    return rows


def train_once(
    cfg,
    *,
    optimizer: str,
    batch: int,
    seq: int,
    steps: int,
    lr: float,
    warmup_ratio: float,
    seed: int = 0,
    eval_batches: int = 4,
    weight_decay: float = 0.01,
    accum_steps: int = 1,
    precision: str = "fp32",
    fused: bool = True,
    mesh=None,
    log_every: Optional[int] = None,
    target_loss: Optional[float] = None,
) -> Dict:
    """Train through the full fused stack; return metrics + loss trajectory.

    The returned dict keeps ``common.train_once``'s keys (train_loss,
    eval_loss, eval_acc, steps, wall_s) and adds ``history`` (logged
    ``{step, loss}`` rows) and, when ``target_loss`` is given,
    ``steps_to_target``.  ``mesh`` runs the step SPMD-sharded (FSDP state +
    data-parallel batch split) — the convergence bench's 8-virtual-device
    production path.
    """
    cfg = fused_model_config(cfg)
    model = build_model(cfg)
    warmup = max(int(round(warmup_ratio * steps)), 1)
    sched = core.warmup_poly_decay(lr, steps, warmup)
    tc = make_train_config(
        optimizer, lr, weight_decay=weight_decay, seed=seed,
        accum_steps=accum_steps, precision=precision, fused=fused,
    )
    le = max(steps // 4, 1) if log_every is None else log_every
    tr = Trainer(model, tc, schedule=sched, mesh=mesh, log_every=le,
                 log_fn=lambda s: None)

    data, src = synthetic_stream(cfg, batch, seq, seed=seed)
    t0 = time.perf_counter()
    hist = tr.fit(data, steps)
    wall = time.perf_counter() - t0

    eval_loss, eval_acc = _evaluate(
        model, tr.state.params, src,
        batch=batch, seq=seq, seed=seed, eval_batches=eval_batches,
    )
    out = {
        "train_loss": hist[-1][LOSS_KEY],
        "eval_loss": eval_loss,
        "eval_acc": eval_acc,
        "steps": steps,
        "wall_s": wall,
        "history": _trajectory(hist),
    }
    if target_loss is not None:
        out["steps_to_target"] = steps_to_target(hist, target_loss)
    return out


def train_stages(
    cfg,
    *,
    optimizer: str,
    stages: Sequence[Stage],
    seed: int = 0,
    eval_batches: int = 4,
    weight_decay: float = 0.01,
    accum_steps: int = 1,
    precision: str = "fp32",
    fused: bool = True,
    mesh=None,
    log_every: int = 1,
    target_loss: Optional[float] = None,
) -> Dict:
    """§4.1 two-stage run through the fused stack (stage-2 re-warm-up).

    ``Trainer.fit_stages`` re-jits per stage, carries the optimizer moments
    across the seq switch, and zeroes the schedule counters so stage 2
    re-warms up from LR 0 — the paper's mixed-batch procedure.  Evaluation
    runs at the final stage's (batch, seq).
    """
    cfg = fused_model_config(cfg)
    model = build_model(cfg)
    tc = make_train_config(
        optimizer, stages[0].learning_rate, weight_decay=weight_decay,
        seed=seed, accum_steps=accum_steps, precision=precision, fused=fused,
    )
    tr = Trainer(model, tc, mesh=mesh, log_every=log_every,
                 log_fn=lambda s: None)
    t0 = time.perf_counter()
    hist = tr.fit_stages(stages, data_seed=seed)
    wall = time.perf_counter() - t0

    last = stages[-1]
    src = SyntheticLM(cfg.vocab_size, seed=1)
    eval_loss, eval_acc = _evaluate(
        model, tr.state.params, src,
        batch=last.batch_size, seq=last.seq_len, seed=seed,
        eval_batches=eval_batches,
    )
    out = {
        "train_loss": hist[-1][LOSS_KEY],
        "eval_loss": eval_loss,
        "eval_acc": eval_acc,
        "steps": sum(s.steps for s in stages),
        "wall_s": wall,
        "history": _trajectory(hist),
        "stages": [
            {"name": s.name, "seq": s.seq_len, "batch": s.batch_size,
             "steps": s.steps, "lr": s.learning_rate, "warmup": s.warmup_steps}
            for s in stages
        ],
    }
    if target_loss is not None:
        out["steps_to_target"] = steps_to_target(hist, target_loss)
    return out


def tuned_adamw(
    cfg,
    *,
    batch: int,
    seq: int,
    steps: int,
    warmup_ratio: float,
    grid: Tuple[float, ...] = ADAMW_TUNING_GRID,
    seed: int = 0,
    **kw,
) -> Dict:
    """Nado et al. baseline: grid-search AdamW's peak LR at this batch size
    and return the best run (by eval loss) with the winning LR attached."""
    best_lr, best = None, None
    for lr in grid:
        out = train_once(
            cfg, optimizer="adamw", batch=batch, seq=seq, steps=steps,
            lr=lr, warmup_ratio=warmup_ratio, seed=seed, **kw,
        )
        if best is None or out["eval_loss"] < best["eval_loss"]:
            best_lr, best = lr, out
    return {"lr": best_lr, **best}
