"""Paper §4.1 mixed-batch training (the 76-minute recipe), CPU-scaled.

Two stages: seq 32 @ batch 32 → seq 128 @ batch 8, with stage-2 re-warm-up.
Claims validated: (a) the stage switch does not destabilize the loss when
re-warm-up is used; (b) ablation — stage 2 *without* re-warm-up (continuing
at the decayed-but-large LR) is worse or less stable.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro import core
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.train import Trainer
from benchmarks.common import bert_nano, csv_row


def _run(rewarmup: bool) -> dict:
    cfg = bert_nano()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    s1 = core.make_stage("s1", 32, 32, 40, base_lr=2.5e-3, base_batch=16,
                         base_warmup_ratio=1 / 40)
    if rewarmup:
        s2 = core.make_stage("s2", 128, 8, 20, base_lr=2.5e-3, base_batch=16,
                             base_warmup_ratio=1 / 40)
    else:
        # ablation: stage 2 keeps a flat large LR (no re-warm-up)
        lr2 = core.sqrt_scaled_lr(2.5e-3, 16, 8)
        s2 = core.Stage("s2_norewarm", 128, 8, 20, core.constant(lr2), lr2, 0)
    tr = Trainer(model, tc, log_every=1, log_fn=lambda s: None)
    t0 = time.perf_counter()
    hist = tr.fit_stages([s1, s2])
    wall = time.perf_counter() - t0
    stage2 = [h["loss/total"] for h in hist if h.get("stage") == 1]
    stage1_end = [h["loss/total"] for h in hist if h.get("stage") == 0][-1]
    return {
        "wall": wall,
        "stage1_end": stage1_end,
        "stage2_max_spike": max(stage2) - stage1_end,
        "stage2_final": stage2[-1],
        "finite": bool(np.isfinite(stage2).all()),
    }


def run() -> List[str]:
    with_rw = _run(rewarmup=True)
    without = _run(rewarmup=False)
    rows = [
        csv_row("mixed_batch/with_rewarmup", with_rw["wall"] / 60 * 1e6,
                f"stage2_final={with_rw['stage2_final']:.4f};"
                f"spike={with_rw['stage2_max_spike']:.4f};finite={with_rw['finite']}"),
        csv_row("mixed_batch/no_rewarmup_ablation", without["wall"] / 60 * 1e6,
                f"stage2_final={without['stage2_final']:.4f};"
                f"spike={without['stage2_max_spike']:.4f};finite={without['finite']}"),
        csv_row("mixed_batch/claim_rewarmup_stable_switch", 0.0,
                f"finite={with_rw['finite']};spike={with_rw['stage2_max_spike']:.4f};"
                f"holds={with_rw['finite'] and with_rw['stage2_max_spike'] < 2.0}"),
        csv_row("mixed_batch/rewarmup_vs_ablation", 0.0,
                f"rewarm_final={with_rw['stage2_final']:.4f};"
                f"norewarm_final={without['stage2_final']:.4f};"
                f"note=nano-scale ablation (paper-scale divergence needs 64K batches)"),
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
