"""Paper Table 3 / App. G analogue: LAMB vs tuned adaptive baselines.

Each baseline gets a small LR grid (the paper grid-searches extensively);
LAMB runs the single untuned recipe.  Claim validated: untuned LAMB matches
or beats every tuned baseline at large batch.
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import bert_nano, csv_row, fixed_epoch_steps
from benchmarks.protocol import train_once

SEQ = 32
BATCH = 48
TOKENS = 16 * SEQ * 450

GRIDS = {
    "adamw": [1e-3, 3e-3, 1e-2],
    "adam": [1e-3, 3e-3, 1e-2],
    "adagrad": [3e-3, 1e-2, 3e-2],
    "momentum": [3e-2, 1e-1, 3e-1],
}
LAMB_LR = 6e-3 * (BATCH / 16) ** 0.5  # untuned recipe from base batch 16


SEEDS = (0, 1, 2)  # seed-averaged: the 150-step nano regime is high-variance


def _mean_acc(cfg, opt, lr, steps):
    import numpy as np

    accs = []
    for seed in SEEDS:
        out = train_once(cfg, optimizer=opt, batch=BATCH, seq=SEQ,
                         steps=steps, lr=lr, warmup_ratio=0.1, seed=seed,
                         eval_batches=8)
        accs.append(0.0 if np.isnan(out["eval_loss"]) else out["eval_acc"])
    return float(np.mean(accs)), out


def run() -> List[str]:
    cfg = bert_nano()
    steps = fixed_epoch_steps(TOKENS, BATCH, SEQ)
    rows = []
    best = {}
    for opt, grid in GRIDS.items():
        # stage 1: pick LR on seed 0; stage 2: seed-average at the best LR
        scores = []
        for lr in grid:
            out = train_once(cfg, optimizer=opt, batch=BATCH, seq=SEQ,
                             steps=steps, lr=lr, warmup_ratio=0.1)
            scores.append((out["eval_loss"], lr, out))
        scores = [(l if not __import__("math").isnan(l) else 1e9, lr, o)
                  for l, lr, o in scores]
        _, lr, _ = min(scores)
        acc, out = _mean_acc(cfg, opt, lr, steps)
        best[opt] = acc
        rows.append(csv_row(
            f"table3/{opt}_tuned", out["wall_s"] / steps * 1e6,
            f"best_lr={lr:.0e};mean_eval_acc={acc:.4f};seeds={len(SEEDS)}",
        ))
    acc, out = _mean_acc(cfg, "lamb", LAMB_LR, steps)
    best["lamb"] = acc
    rows.append(csv_row(
        "table3/lamb_untuned", out["wall_s"] / steps * 1e6,
        f"lr={LAMB_LR:.2e};mean_eval_acc={acc:.4f};seeds={len(SEEDS)}",
    ))
    # paper metric: accuracy (App. H); untuned LAMB within 0.02 of the best
    # grid-tuned baseline.  NOTE: Table 3 is a full-convergence claim (90
    # epochs @ ImageNet scale); at a 150-step nano budget it is the hardest
    # to reproduce — result reported as measured.
    holds = best["lamb"] >= max(v for k, v in best.items() if k != "lamb") - 0.02
    rows.append(csv_row(
        "table3/claim_untuned_lamb_competitive", 0.0,
        ";".join(f"{k}_acc={v:.4f}" for k, v in sorted(best.items()))
        + f";holds={holds};note=150-step nano regime (paper claim is at full convergence)",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
