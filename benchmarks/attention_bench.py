"""Attention microbenchmark: dense masked softmax vs the flash path.

Compares the model's dense ``_sdpa`` (materializes the (S, T) fp32 score
matrix, plus the (B, 1, S, T) mask bias) against ``flash_sdpa`` — the
differentiable flash path this repo trains BERT MLM through — on the
bidirectional-encoder workload, forward and forward+backward, measuring
wall time and the compiled executable's peak temp (activation) memory.

On this box the flash backend is the chunked-XLA scan (the Pallas kernels
need a TPU); it runs the same blockwise online-softmax + recompute-based
backward as the kernels, so the *shape* of the claim — flash wins on time
and peak activation memory once S is large enough that (S, T) temps
dominate — is measured for real, not modeled.  Results land in
``BENCH_attention.json`` next to the CSV rows.

    PYTHONPATH=src python benchmarks/attention_bench.py [--full]
"""
from __future__ import annotations

import json
import pathlib
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_sdpa, resolve_flash_backend
from repro.models.layers.attention import _mask_bias, _sdpa

try:
    from benchmarks.common import csv_row, provenance_header
except ModuleNotFoundError:  # run as a script: `python benchmarks/attention_bench.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_row, provenance_header

OUT_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_attention.json"

B, H, HKV, D = 4, 4, 4, 64        # bert-family head geometry, CPU-scale batch
SEQS = (256, 512)                  # --full adds 1024
CLAIM_S = 512                      # acceptance: flash wins at S >= 512


def _time_ms(fn, args, iters=5) -> float:
    jax.block_until_ready(fn(*args))   # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def _temp_bytes(fn, args) -> int:
    """Peak temp (activation workspace) memory of the compiled fn."""
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0) or 0)
    except Exception:
        return 0


def _qkv(s: int):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.standard_normal((B, s, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, s, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, s, HKV, D)), jnp.float32)
    return q, k, v


def _variants(s: int):
    pos = jnp.broadcast_to(jnp.arange(s)[None], (B, s))
    kv_pos = jnp.arange(s, dtype=jnp.int32)

    def dense(q, k, v):
        # the model's dense path: (B,1,S,T) bias + fp32 (S,T) softmax
        bias = _mask_bias(pos, kv_pos, None, causal=False, window=None)
        return _sdpa(q, k, v, bias, HKV)

    def flash(q, k, v):
        return flash_sdpa(q, k, v, causal=False)

    return dense, flash


def _loss(f):
    return lambda q, k, v: jnp.sum(jnp.square(f(q, k, v)))


def run(full: bool = False) -> List[str]:
    backend = resolve_flash_backend("auto")
    seqs = SEQS + ((1024,) if full else ())
    rows, results = [], []
    for s in seqs:
        args = _qkv(s)
        dense, flash = _variants(s)
        entry = {"seq": s, "batch": B, "heads": H, "head_dim": D,
                 "flash_backend": backend}
        for mode, wrap in (("fwd", lambda f: f),
                           ("fwd_bwd", lambda f: jax.grad(_loss(f), (0, 1, 2)))):
            dj = jax.jit(wrap(dense))
            fj = jax.jit(wrap(flash))
            d_ms, f_ms = _time_ms(dj, args), _time_ms(fj, args)
            d_mem, f_mem = _temp_bytes(wrap(dense), args), _temp_bytes(
                wrap(flash), args)
            entry[mode] = {
                "dense_ms": round(d_ms, 2), "flash_ms": round(f_ms, 2),
                "dense_temp_bytes": d_mem, "flash_temp_bytes": f_mem,
            }
            rows.append(csv_row(
                f"attention/{mode}_s{s}_dense", d_ms * 1e3,
                f"temp_bytes={d_mem}"))
            rows.append(csv_row(
                f"attention/{mode}_s{s}_flash_{backend}", f_ms * 1e3,
                f"temp_bytes={f_mem};speedup={d_ms / max(f_ms, 1e-9):.2f}x"))
        results.append(entry)

    # the paper-scale claim: flash fwd+bwd wins time AND peak temp memory
    # once S >= 512 (where the dense (S,T) temps dominate the step)
    claim = [r for r in results if r["seq"] >= CLAIM_S]
    holds = bool(claim) and all(
        r["fwd_bwd"]["flash_ms"] < r["fwd_bwd"]["dense_ms"]
        and (r["fwd_bwd"]["flash_temp_bytes"] < r["fwd_bwd"]["dense_temp_bytes"]
             or not r["fwd_bwd"]["dense_temp_bytes"])
        for r in claim
    )
    OUT_JSON.write_text(json.dumps(
        {"provenance": provenance_header(time.time()),
         "results": results, "claim_s": CLAIM_S, "holds": holds}, indent=2))
    rows.append(csv_row(
        "attention/flash_beats_dense_fwd_bwd", 0.0,
        f"s>={CLAIM_S};holds={int(holds)}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="also run S=1024")
    print("\n".join(run(full=ap.parse_args().full)))
