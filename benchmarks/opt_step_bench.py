"""Optimizer-step microbenchmark: fused Pallas LAMB vs unfused chain.

On CPU the Pallas kernel runs in interpret mode, so wall time favors the
unfused XLA path — the derived column therefore ALSO reports the HBM-traffic
model (bytes per param per step) that determines the TPU outcome:
unfused ≈ 21 N·4B of HBM traffic, fused ≈ 10 N·4B (see kernels/lamb_update).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import core, optim
from repro.kernels import fused_lamb
from benchmarks.common import csv_row

SHAPES = {"layers/w": (8, 512, 512), "emb": (4096, 512), "norm": (512,)}


def _params(rng):
    return {k: jnp.asarray(rng.standard_normal(v), jnp.float32)
            for k, v in SHAPES.items()}


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    rng = np.random.default_rng(0)
    params = _params(rng)
    grads = _params(rng)
    la = {"layers/w": 0, "emb": -1, "norm": -1}
    n = sum(int(np.prod(s)) for s in SHAPES.values())

    o1 = core.lamb(1e-3, weight_decay=0.01, layer_axes=la)
    s1 = o1.init(params)
    step1 = jax.jit(lambda g, s, p: o1.update(g, s, p))
    us1 = _time(step1, grads, s1, params)

    o2 = fused_lamb(1e-3, weight_decay=0.01, layer_axes=la, interpret=True)
    s2 = o2.init(params)
    step2 = jax.jit(lambda g, s, p: o2.update(g, s, p))
    us2 = _time(step2, grads, s2, params, iters=5)

    hbm_unfused = 21 * n * 4
    hbm_fused = 10 * n * 4
    return [
        csv_row("opt_step/unfused_lamb", us1,
                f"params={n};hbm_model_bytes={hbm_unfused}"),
        csv_row("opt_step/fused_pallas_lamb_interpret", us2,
                f"params={n};hbm_model_bytes={hbm_fused};"
                f"traffic_reduction={hbm_unfused / hbm_fused:.2f}x"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
