"""Large-batch scaling bench: accumulation × precision × fused-LAMB sweep.

The question the paper's recipe answers is "how do you reach a global batch
the hardware can't hold in one shot?" — and the train step's three knobs
compose into the answer:

  * ``accum_steps k`` slices the global batch into k microbatches
    (activation memory ∝ 1/k, but each extra microbatch costs a backward
    launch and skinnier matmuls);
  * ``precision bf16`` halves activation bytes, so a fixed memory budget
    fits a 2× microbatch → *half the accumulation steps* at the same global
    batch;
  * ``use_fused_lamb`` replaces the ~21 N-traffic unfused optimizer chain
    with the fused update (~10 N; Pallas on TPU, fused XLA elsewhere).

The headline row holds the global batch and an activation-memory budget
fixed: fp32 needs ``2k`` accumulation steps where bf16 needs ``k``, so the
fused+bf16 step is strictly faster than the unfused fp32 step for the same
optimizer semantics.  Wall time is min-of-N interleaved (robust to a noisy
shared box); the optimizer-traffic column is the deterministic model that
decides the TPU outcome (see kernels/lamb_update and opt_step_bench).
"""
from __future__ import annotations

import pathlib
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.bert_large import tiny as bert_tiny
from repro.data import make_batch
from repro.models import build_model
from repro.train.step import make_train_step

try:
    from benchmarks.common import csv_row
except ModuleNotFoundError:  # run as a script: `python benchmarks/scaling_bench.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_row

GLOBAL_BATCH = 16
SEQ = 32
REPS = 12

# Activation-memory budget (bytes) for the fixed-memory comparison: sized so
# the fp32 path fits microbatch=2 (accum=8) and bf16 fits microbatch=4
# (accum=4) at the same global batch.
MEM_BUDGET_TOKENS_BYTES = 2 * SEQ * 4  # microbatch-2 fp32 activations / (S*d)


def _bench_model():
    cfg = bert_tiny(vocab=2048).replace(
        name="bert-scaling", n_layers=4, d_model=192, n_heads=4, n_kv_heads=4,
        d_ff=512,
    )
    return build_model(cfg)


def _accum_for(precision: str) -> int:
    """Accumulation steps forced by the fixed activation-memory budget."""
    bytes_per_tok = 4 if precision == "fp32" else 2
    micro = max(MEM_BUDGET_TOKENS_BYTES // (SEQ * bytes_per_tok), 1)
    return max(GLOBAL_BATCH // micro, 1)


def run() -> List[str]:
    model = _bench_model()
    n = model.param_count()
    batch = jax.tree.map(
        jnp.asarray,
        make_batch(model.cfg, np.random.default_rng(0), GLOBAL_BATCH, SEQ),
    )
    key = jax.random.key(0)

    configs: Dict[str, TrainConfig] = {}

    def add(name: str, **kw) -> None:
        configs[name] = TrainConfig(optimizer="lamb", **kw)

    # fixed-memory headline: same global batch, budget-implied accumulation
    a32, a16 = _accum_for("fp32"), _accum_for("bf16")
    add("fixed_mem/unfused_fp32", accum_steps=a32)
    add("fixed_mem/unfused_bf16", accum_steps=a16, precision="bf16")
    add("fixed_mem/fused_bf16", accum_steps=a16, precision="bf16",
        use_fused_lamb=True)
    # accumulation sweep at bf16+fused (the 1/k activation-memory curve)
    for a in (1, 2, 4, 8):
        add(f"accum_sweep/bf16_fused_accum{a}", accum_steps=a,
            precision="bf16", use_fused_lamb=True)
    # precision/fused matrix at accum=1 (pure step-dtype/optimizer effect)
    add("matrix/unfused_fp32", )
    add("matrix/fused_bf16", precision="bf16", use_fused_lamb=True)

    # compile everything up front, then interleave timed reps so machine
    # noise hits every config equally; min-of-N estimates the true cost.
    steps = {}
    for name, tc in configs.items():
        init_fn, step_fn = make_train_step(model, tc)
        st = jax.jit(init_fn)(key)
        sj = jax.jit(step_fn, donate_argnums=(0,))
        st, _ = sj(st, batch)
        jax.block_until_ready(st)
        steps[name] = [sj, st]
    times: Dict[str, List[float]] = {name: [] for name in configs}
    for _ in range(REPS):
        for name, slot in steps.items():
            sj, st = slot
            t0 = time.perf_counter()
            st, _ = sj(st, batch)
            jax.block_until_ready(st)
            times[name].append(time.perf_counter() - t0)
            slot[1] = st

    ms = {name: min(ts) * 1e3 for name, ts in times.items()}
    rows = []
    for name, tc in configs.items():
        fused = tc.use_fused_lamb
        traffic = (10 if fused else 21) * n * 4
        rows.append(csv_row(
            f"scaling/{name}", ms[name] * 1e3,
            f"global_batch={GLOBAL_BATCH};seq={SEQ};accum={tc.grad_accum_steps};"
            f"precision={tc.precision};fused={int(fused)};"
            f"opt_traffic_bytes={traffic}",
        ))

    base = ms["fixed_mem/unfused_fp32"]
    head = ms["fixed_mem/fused_bf16"]
    rows.append(csv_row(
        "scaling/claim_fused_bf16_beats_unfused_fp32", head * 1e3,
        f"speedup={base / head:.2f}x;baseline_ms={base:.1f};"
        f"same_global_batch={GLOBAL_BATCH};fp32_accum={a32};bf16_accum={a16};"
        f"holds={int(head < base)}",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
