"""Paper Tables 4-5: the untuned scaling recipe itself (exact, fast).

Emits the LR / warmup-ratio the recipe produces at every batch size in the
paper's tables and checks them against the paper's closed forms.
"""
from __future__ import annotations

from typing import List

from repro import core
from benchmarks.common import csv_row

BERT = {  # batch: (2^x in lr = 5/(2^x·1e3), warmup denominator)
    512: (3.0, 320), 1024: (2.5, 160), 2048: (2.0, 80), 4096: (1.5, 40),
    8192: (1.0, 20), 16384: (0.5, 10), 32768: (0.0, 5),
}


def run() -> List[str]:
    rows = []
    all_ok = True
    for batch, (x, denom) in sorted(BERT.items()):
        want_lr = 5 / (2**x * 1e3)
        want_ratio = 1 / denom
        sched, info = core.untuned_lamb_schedule(
            batch, total_steps=512_000_000 // (batch * 32)  # fixed-epoch steps
        )
        ok = (
            abs(info["learning_rate"] - want_lr) < 1e-12
            and abs(info["warmup_ratio"] - want_ratio) < 1e-12
        )
        all_ok &= ok
        rows.append(csv_row(
            f"table4/batch{batch}", 0.0,
            f"lr={info['learning_rate']:.6g};warmup_ratio={info['warmup_ratio']:.6g};"
            f"matches_paper={ok}",
        ))
    # mixed-batch plan (Table 1 last row: 8599 iterations)
    plan = core.bert_mixed_batch_plan()
    rows.append(csv_row(
        "table4/mixed_batch_plan", 0.0,
        f"stage1={plan[0].batch_size}x{plan[0].seq_len}x{plan[0].steps};"
        f"stage2={plan[1].batch_size}x{plan[1].seq_len}x{plan[1].steps};"
        f"total_iters={plan[0].steps + plan[1].steps};matches_paper={plan[0].steps + plan[1].steps == 8599}",
    ))
    rows.append(csv_row("table4/claim_recipe_exact", 0.0, f"holds={all_ok}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
