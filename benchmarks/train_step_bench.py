"""End-to-end train-step benchmark: dense MLM head vs the fused-CE head.

The dense head projects every position to the vocab and log-softmaxes a
``(B, S, V)`` tensor even though MLM supervises ~15% of positions; the
fused head (``use_fused_ce_head``) gathers supervised positions first and
streams the CE over vocab chunks, so that tensor never exists.  This
benchmark measures the *whole step* — forward, backward, LAMB update —
at bert-large vocab/sequence geometry (V=30522, S up to 512; width and
depth are CPU-scaled like ``benchmarks/common.bert_cpu``), recording wall
time, tokens/s, and the compiled executable's peak temp memory, and
verifying from the compiled HLO that the fused program contains **no**
``(B, S, V)`` tensor of any dtype.

On this box the CE backend is the chunked-XLA scan (the Pallas kernels
need a TPU) — the same custom-VJP math, so the shape of the claim (fused
wins step time *and* activation memory once S·V dominates) is measured
for real.  Results land in ``BENCH_train_step.json``.

    PYTHONPATH=src python benchmarks/train_step_bench.py [--full]
"""
from __future__ import annotations

import json
import pathlib
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bert_large import CONFIG as BERT_LARGE
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.kernels import resolve_ce_backend
from repro.models import build_model
from repro.train.step import make_train_step

try:
    from benchmarks.common import csv_row, provenance_header
except ModuleNotFoundError:  # run as a script
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_row, provenance_header

OUT_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_train_step.json"

B = 2
SEQS = (256, 512)                 # --full adds 1024
CLAIM_S = 512                     # acceptance: fused wins at S >= 512
VOCAB = BERT_LARGE.vocab_size     # 30522 — the real head width


def _cfg(seq: int, fused: bool):
    """bert-large vocab + sequence geometry at CPU-runnable width/depth."""
    return BERT_LARGE.replace(
        name=f"bert-head-{'fused' if fused else 'dense'}",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
        use_fused_ce_head=fused,
    )


def _step(cfg):
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    init_fn, step_fn = make_train_step(model, tc)
    return jax.jit(init_fn), jax.jit(step_fn, donate_argnums=(0,))


def _time_step(step, state, batch, iters=2):
    state, _ = step(state, batch)          # compile + warm (donated: reuse out)
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(iters):
        state, metrics = step(state, batch)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / iters, state


def _compiled_stats(cfg, state, batch, seq: int):
    """Peak/temp memory + (B, S, V)-tensor scan of the compiled step HLO."""
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    _, step_fn = make_train_step(model, tc)
    out = {"temp_bytes": 0, "peak_bytes": 0, "has_bsv_tensor": None}
    try:
        compiled = jax.jit(step_fn).lower(state, batch).compile()
        ma = compiled.memory_analysis()
        out["temp_bytes"] = int(getattr(ma, "temp_size_in_bytes", 0) or 0)
        out["peak_bytes"] = int(getattr(ma, "peak_size_in_bytes", 0) or 0) or (
            out["temp_bytes"]
            + int(getattr(ma, "argument_size_in_bytes", 0) or 0)
            + int(getattr(ma, "output_size_in_bytes", 0) or 0)
        )
        hlo = compiled.as_text()
        # any dtype: f32[2,512,30522], bf16[...], etc.
        out["has_bsv_tensor"] = f"[{B},{seq},{VOCAB}]" in hlo
    except Exception as e:  # memory_analysis/HLO access is backend-dependent
        out["error"] = f"{type(e).__name__}: {e}"
    return out


def run(full: bool = False) -> List[str]:
    backend = resolve_ce_backend("auto")
    seqs = SEQS + ((1024,) if full else ())
    rows, results = [], []
    for s in seqs:
        entry = {"seq": s, "batch": B, "vocab": VOCAB, "ce_backend": backend}
        batch = None
        for fused in (False, True):
            cfg = _cfg(s, fused)
            if batch is None:
                batch = jax.tree.map(
                    jnp.asarray, make_batch(cfg, np.random.default_rng(s), B, s)
                )
            init_jit, step_jit = _step(cfg)
            state = init_jit(jax.random.key(0))
            stats = _compiled_stats(cfg, state, batch, s)
            dt, _ = _time_step(step_jit, state, batch)
            key = "fused" if fused else "dense"
            entry[key] = {
                "step_ms": round(dt * 1e3, 2),
                "tokens_per_s": round(B * s / dt, 1),
                **stats,
            }
            rows.append(csv_row(
                f"train_step/s{s}_{key}", dt * 1e6,
                f"tokens_per_s={entry[key]['tokens_per_s']};"
                f"temp_bytes={stats['temp_bytes']};"
                f"bsv_tensor={stats['has_bsv_tensor']}"))
        results.append(entry)

    # the headline claim: at S >= CLAIM_S the fused head beats the dense head
    # on BOTH step time and compiled peak/temp memory, and its compiled HLO
    # contains no (B, S, V) tensor while the dense one does
    claim = [r for r in results if r["seq"] >= CLAIM_S]
    holds = bool(claim) and all(
        r["fused"]["step_ms"] < r["dense"]["step_ms"]
        # memory stats must actually exist — an unmeasured comparison
        # (temp/peak == 0 on exotic backends) must not count as a win
        and 0 < r["fused"]["temp_bytes"] < r["dense"]["temp_bytes"]
        and 0 < r["fused"]["peak_bytes"] < r["dense"]["peak_bytes"]
        and r["fused"]["has_bsv_tensor"] is False
        for r in claim
    )
    OUT_JSON.write_text(json.dumps(
        {"provenance": provenance_header(time.time()),
         "results": results, "claim_s": CLAIM_S, "holds": holds}, indent=2))
    rows.append(csv_row(
        "train_step/fused_ce_beats_dense", 0.0,
        f"s>={CLAIM_S};holds={int(holds)}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="also run S=1024")
    print("\n".join(run(full=ap.parse_args().full)))
