"""Paper Table 2 analogue: LAMB vs LARS across batch sizes (attention model).

Claim validated: LAMB beats LARS at every batch size on a BERT-family
(attention) model, and LARS degrades faster at large batch (paper: LARS
diverges at 32K while LAMB reaches 91.475).
"""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import bert_nano, csv_row, fixed_epoch_steps
from benchmarks.protocol import recipe, train_once

SEQ = 32
BASE_BATCH = 16
TOKENS = BASE_BATCH * SEQ * 400
# LARS's base LR (0.3, layerwise-SGD scale) comes from protocol.UNTUNED_BASE_LR


def run(batches=(16, 64)) -> List[str]:
    cfg = bert_nano()
    rows, results = [], {}
    for opt in ("lamb", "lars"):
        for b in batches:
            steps = fixed_epoch_steps(TOKENS, b, SEQ)
            r = recipe(opt, b, base_batch=BASE_BATCH)
            t0 = time.perf_counter()
            out = train_once(cfg, optimizer=opt, batch=b, seq=SEQ,
                             steps=steps, lr=r["lr"],
                             warmup_ratio=r["warmup_ratio"])
            us = (time.perf_counter() - t0) / steps * 1e6
            results[(opt, b)] = out
            rows.append(csv_row(
                f"table2/{opt}_batch{b}", us,
                f"eval_loss={out['eval_loss']:.4f};eval_acc={out['eval_acc']:.4f}",
            ))
    import math

    for b in batches:
        # paper metric is accuracy; a diverged (NaN) run loses outright
        # (Table 2: "LARS ... diverge" at 32K)
        acc = lambda o: (
            -1.0 if math.isnan(results[(o, b)]["eval_loss"])
            else results[(o, b)]["eval_acc"]
        )
        lamb_better = acc("lamb") >= acc("lars")
        rows.append(csv_row(
            f"table2/claim_lamb_beats_lars_batch{b}", 0.0,
            f"lamb_acc={acc('lamb'):.4f};lars_acc={acc('lars'):.4f};"
            f"holds={lamb_better}",
        ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
