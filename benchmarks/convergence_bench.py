"""Convergence-quality bench: steps-to-target vs global batch (Table 1 study).

The paper's central quality claim is a *convergence* claim: under the fixed-
epoch protocol the step budget shrinks as batch grows, and LAMB still reaches
the target metric where AdamW degrades (Table 1 / §4.1).  This bench runs
that study end-to-end through the full production path — ``Trainer`` on the
8-virtual-device mesh with flash attention, the fused CE head, the sharded
fused-LAMB update, gradient accumulation and bf16 compute — on the
deterministic synthetic-MLM corpus, CPU-scaled so batch 8 ≙ the paper's 512
and batch 512 ≙ its 32768 (``PAPER_SCALE`` = 64).

Per optimizer × global batch it records the logged loss trajectory and
reduces it to **steps-to-target-loss** (and examples-to-target, the scaling
metric: a perfect large-batch optimizer holds it constant).  LAMB and LANS
run the untuned recipe (sqrt LR + linear-epoch warmup, Table 4's base
warmup); AdamW is the Nado-et-al. baseline: its peak LR is grid-searched at
every batch size.  A §4.1 two-stage seq32→seq64 run (re-warm-up via
``core.mixed_batch``) rides along per recipe optimizer.

Claims (acceptance): LAMB's large-batch examples-to-target degradation is no
worse than tuned AdamW's, LAMB still reaches the target at the 32k-equivalent
batch, and stage 2 keeps improving after the re-warm-up.

Like the sharding bench, the mesh half must set XLA_FLAGS before jax
initializes, so ``run()`` re-executes this file as a ``--child`` subprocess.

    PYTHONPATH=src python benchmarks/convergence_bench.py [--fast] [--out F]
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time
from typing import Dict, List, Optional

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_JSON = ROOT / "BENCH_convergence.json"

SEQ = 32
BASE_BATCH = 8
PAPER_SCALE = 64                  # cpu batch × 64 = paper batch
BATCHES = (8, 64, 512)            # ≙ paper 512 / 4096 / 32768
STEPS_BASE = 800                  # fixed-epoch budget: BASE_BATCH·SEQ·STEPS_BASE tokens
TARGET_LOSS = 4.5                 # synthetic-MLM train-loss target (start ≈ ln 512 ≈ 6.24)
PRECISION = "bf16"
ACCUM = {512: 4}                  # production large-batch config: 4 accumulation slices
MESH_SPEC = "data=8,model=1"
RECIPE_OPTIMIZERS = ("lamb", "lans")   # untuned recipe (never re-tuned per batch)
ADAMW_GRID = (1e-3, 3e-3)              # tuned baseline: grid-searched per batch
BASE_WARMUP_RATIO = 1.0 / 320.0        # paper Table 4 (1/40 would consume the
                                       # whole 32k-equivalent step budget)

FAST_BATCHES = (8, 64)
FAST_STEPS_BASE = 150
FAST_ADAMW_GRID = (1e-3,)

UNREACHED_PENALTY = 2.0  # unreached target costs 2× the full budget's examples


def _examples_to_target(entry: Dict) -> float:
    if entry["steps_to_target"] is not None:
        return float(entry["steps_to_target"] * entry["batch"])
    return UNREACHED_PENALTY * entry["steps"] * entry["batch"]


def _child(fast: bool) -> Dict:
    """Runs under --xla_force_host_platform_device_count=8 (see run())."""
    from benchmarks import protocol
    from benchmarks.common import bert_nano, fixed_epoch_steps
    from repro.core import make_stage
    from repro.launch.mesh import make_mesh_from_spec

    mesh = make_mesh_from_spec(MESH_SPEC)
    cfg = bert_nano()
    batches = FAST_BATCHES if fast else BATCHES
    steps_base = FAST_STEPS_BASE if fast else STEPS_BASE
    grid = FAST_ADAMW_GRID if fast else ADAMW_GRID
    tokens = BASE_BATCH * SEQ * steps_base

    def one(opt: str, b: int, lr: float, warmup_ratio: float,
            keep_history: bool = True) -> Dict:
        steps = fixed_epoch_steps(tokens, b, SEQ)
        out = protocol.train_once(
            cfg, optimizer=opt, batch=b, seq=SEQ, steps=steps, lr=lr,
            warmup_ratio=warmup_ratio, mesh=mesh, precision=PRECISION,
            accum_steps=ACCUM.get(b, 1), target_loss=TARGET_LOSS,
            log_every=max(steps // 200, 1), eval_batches=4,
        )
        entry = {
            "optimizer": opt, "batch": b, "paper_batch": b * PAPER_SCALE,
            "steps": steps, "lr": lr, "warmup_ratio": warmup_ratio,
            "accum_steps": ACCUM.get(b, 1),
            "steps_to_target": out["steps_to_target"],
            "target_reached": out["steps_to_target"] is not None,
            "train_loss": out["train_loss"], "eval_loss": out["eval_loss"],
            "eval_acc": out["eval_acc"], "wall_s": out["wall_s"],
        }
        entry["examples_to_target"] = _examples_to_target(entry)
        if keep_history:
            entry["history"] = out["history"]
        return entry

    runs: List[Dict] = []
    for opt in RECIPE_OPTIMIZERS:
        for b in batches:
            r = protocol.recipe(opt, b, base_batch=BASE_BATCH,
                                base_warmup_ratio=BASE_WARMUP_RATIO)
            runs.append({**one(opt, b, r["lr"], r["warmup_ratio"]),
                         "tuned": False})

    # Nado et al.: the baseline's peak LR is re-tuned at every batch size
    # (best eval loss wins; NaN loses outright).
    for b in batches:
        wr = protocol.recipe("adamw", b, base_batch=BASE_BATCH,
                             base_warmup_ratio=BASE_WARMUP_RATIO)["warmup_ratio"]
        candidates = [one("adamw", b, lr, wr, keep_history=len(grid) == 1)
                      for lr in grid]
        score = lambda e: (e["eval_loss"] if e["eval_loss"] == e["eval_loss"]
                           else float("inf"))
        best = min(candidates, key=score)
        if "history" not in best:
            best = one("adamw", b, best["lr"], wr)  # re-run winner w/ history
        best["tuned"] = True
        best["grid"] = {f"{c['lr']:.0e}": c["eval_loss"] for c in candidates}
        runs.append(best)

    # §4.1 two-stage mixed-batch: 9:1 token split, seq 32→64 with the batch
    # halved (the paper's 65536/seq128 → 32768/seq512 shape), stage-2
    # re-warm-up from LR 0 with carried moments.
    s1_batch, s2_batch = 64, 32
    s1_steps = max(int(0.9 * tokens) // (s1_batch * SEQ), 2)
    s2_steps = max(int(0.1 * tokens) // (s2_batch * 2 * SEQ), 2)
    two_stage: Dict[str, Dict] = {}
    for opt in RECIPE_OPTIMIZERS:
        stages = [
            make_stage("stage1_seq32", SEQ, s1_batch, s1_steps,
                       base_lr=protocol.UNTUNED_BASE_LR[opt],
                       base_batch=BASE_BATCH,
                       base_warmup_ratio=BASE_WARMUP_RATIO),
            make_stage("stage2_seq64_rewarmup", 2 * SEQ, s2_batch, s2_steps,
                       base_lr=protocol.UNTUNED_BASE_LR[opt],
                       base_batch=BASE_BATCH,
                       base_warmup_ratio=BASE_WARMUP_RATIO),
        ]
        out = protocol.train_stages(
            cfg, optimizer=opt, stages=stages, mesh=mesh,
            precision=PRECISION, target_loss=TARGET_LOSS, eval_batches=4,
        )
        s2_rows = [h for h in out["history"] if h.get("stage") == 1]
        two_stage[opt] = {
            "stages": out["stages"],
            "history": out["history"],
            "train_loss": out["train_loss"],
            "eval_loss": out["eval_loss"],
            "eval_acc": out["eval_acc"],
            "wall_s": out["wall_s"],
            "stage2_first_loss": s2_rows[0]["loss"] if s2_rows else None,
            "stage2_final_loss": s2_rows[-1]["loss"] if s2_rows else None,
            "stage2_improves": bool(
                s2_rows and s2_rows[-1]["loss"] == s2_rows[-1]["loss"]
                and s2_rows[-1]["loss"] <= s2_rows[0]["loss"]
            ),
        }

    # ---- claims ------------------------------------------------------
    small, big = batches[0], batches[-1]

    def entry(opt, b):
        return next(r for r in runs if r["optimizer"] == opt and r["batch"] == b)

    degradation = {
        opt: _examples_to_target(entry(opt, big))
        / _examples_to_target(entry(opt, small))
        for opt in (*RECIPE_OPTIMIZERS, "adamw")
    }
    claims = {
        "lamb_scales_no_worse_than_tuned_adamw": {
            "lamb_examples_degradation": degradation["lamb"],
            "adamw_examples_degradation": degradation["adamw"],
            "holds": degradation["lamb"] <= degradation["adamw"],
        },
        "lamb_reaches_target_at_32k_equivalent": {
            "batch": big, "paper_batch": big * PAPER_SCALE,
            "steps_to_target": entry("lamb", big)["steps_to_target"],
            "holds": entry("lamb", big)["target_reached"],
        },
        "rewarmup_stage2_improves": {
            opt: two_stage[opt]["stage2_improves"] for opt in RECIPE_OPTIMIZERS
        } | {"holds": all(two_stage[o]["stage2_improves"]
                          for o in RECIPE_OPTIMIZERS)},
    }
    return {
        "protocol": {
            "seq": SEQ, "tokens": tokens, "base_batch": BASE_BATCH,
            "paper_scale": PAPER_SCALE, "batches": list(batches),
            "target_loss": TARGET_LOSS, "precision": PRECISION,
            "mesh": MESH_SPEC, "base_warmup_ratio": BASE_WARMUP_RATIO,
            "adamw_grid": list(grid), "fast": fast,
            "unreached_penalty": UNREACHED_PENALTY,
        },
        "runs": runs,
        "two_stage": two_stage,
        "degradation": degradation,
        "claims": claims,
    }


def run(fast: bool = False, out: Optional[pathlib.Path] = None) -> List[str]:
    try:
        from benchmarks.common import csv_row, provenance_header
    except ModuleNotFoundError:  # run as a script
        sys.path.insert(0, str(ROOT))
        from benchmarks.common import csv_row, provenance_header

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    # the child needs repro.* AND benchmarks.* importable regardless of how
    # the parent was launched (script, -m benchmarks.run, pytest, ...)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), str(ROOT), env.get("PYTHONPATH", "")]
    )
    argv = [sys.executable, str(pathlib.Path(__file__).resolve()), "--child"]
    if fast:
        argv.append("--fast")
    proc = subprocess.run(argv, capture_output=True, text=True, timeout=3600,
                          cwd=ROOT, env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"convergence_bench child failed:\n{proc.stderr[-2000:]}")
    report = json.loads(proc.stdout.splitlines()[-1])
    # the header describes the *parent* environment; the child's virtual
    # 8-device mesh spec is recorded in report["protocol"]["mesh"]
    report = {"provenance": provenance_header(time.time()), **report}
    (out or OUT_JSON).write_text(json.dumps(report, indent=2))

    rows = []
    for r in report["runs"]:
        stt = r["steps_to_target"]
        rows.append(csv_row(
            f"convergence/{r['optimizer']}_batch{r['batch']}"
            + ("_tuned" if r.get("tuned") else ""),
            r["wall_s"] / max(r["steps"], 1) * 1e6,
            f"paper_batch={r['paper_batch']};steps={r['steps']};"
            f"steps_to_target={stt if stt is not None else 'unreached'};"
            f"eval_acc={r['eval_acc']:.4f}",
        ))
    for opt, ts in report["two_stage"].items():
        rows.append(csv_row(
            f"convergence/two_stage_{opt}", 0.0,
            f"stage2_first={ts['stage2_first_loss']:.4f};"
            f"stage2_final={ts['stage2_final_loss']:.4f};"
            f"improves={ts['stage2_improves']}",
        ))
    c = report["claims"]
    rows.append(csv_row(
        "convergence/claim_lamb_scales_no_worse_than_tuned_adamw", 0.0,
        f"lamb_deg={c['lamb_scales_no_worse_than_tuned_adamw']['lamb_examples_degradation']:.2f}x;"
        f"adamw_deg={c['lamb_scales_no_worse_than_tuned_adamw']['adamw_examples_degradation']:.2f}x;"
        f"holds={c['lamb_scales_no_worse_than_tuned_adamw']['holds']}",
    ))
    rows.append(csv_row(
        "convergence/claim_lamb_reaches_target_at_32k_equiv", 0.0,
        f"steps_to_target={c['lamb_reaches_target_at_32k_equivalent']['steps_to_target']};"
        f"holds={c['lamb_reaches_target_at_32k_equivalent']['holds']}",
    ))
    rows.append(csv_row(
        "convergence/claim_rewarmup_stage2_improves", 0.0,
        f"holds={c['rewarmup_stage2_improves']['holds']}",
    ))
    return rows


if __name__ == "__main__":
    if "--child" in sys.argv:
        print(json.dumps(_child(fast="--fast" in sys.argv)))
    else:
        fast = "--fast" in sys.argv
        out = None
        if "--out" in sys.argv:
            out = pathlib.Path(sys.argv[sys.argv.index("--out") + 1])
        print("\n".join(run(fast=fast, out=out)))
