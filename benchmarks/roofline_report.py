"""Roofline report: renders the dry-run JSONL (§Dry-run / §Roofline tables).

Reads benchmarks/results/*.jsonl produced by repro.launch.dryrun and prints
the per-(arch × shape × mesh) three-term roofline with dominant bottleneck.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(pattern: str = "dryrun_baseline_v2.jsonl") -> List[dict]:
    recs = []
    for path in glob.glob(os.path.join(RESULTS, pattern)):
        with open(path) as f:
            recs.extend(json.loads(l) for l in f if l.strip())
    return recs


def run() -> List[str]:
    recs = load()
    if not recs:
        recs = load("dryrun_baseline.jsonl")
    rows = []
    seen = set()
    for r in recs:
        key = (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
        if key in seen:
            continue
        seen.add(key)
        if r.get("status") == "skipped":
            rows.append(csv_row(
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                f"skipped;{r['note']}"))
            continue
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            rl[max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: rl[k])] * 1e6,
            f"dom={rl['dominant']};compute_ms={rl['compute_s']*1e3:.2f};"
            f"memory_ms={rl['memory_s']*1e3:.2f};"
            f"collective_ms={rl['collective_s']*1e3:.2f};"
            f"useful_flop_frac={rl['useful_fraction']:.3f};"
            f"args_GB={r['memory'].get('argument_size_in_bytes', 0)/1e9:.2f}",
        ))
    n_ok = sum(1 for r in recs if r.get("status") == "ok")
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    rows.append(csv_row("roofline/coverage", 0.0,
                        f"ok={n_ok};skipped={n_skip}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
