#!/usr/bin/env python
"""CI convergence gate: the fast-tier batch-scaling study vs its baseline.

    PYTHONPATH=src python scripts/convergence_gate.py            # gate
    PYTHONPATH=src python scripts/convergence_gate.py --write-baseline
    PYTHONPATH=src python scripts/convergence_gate.py --from-json BENCH.json

Runs ``benchmarks/convergence_bench.py --fast`` (LAMB / LANS / tuned AdamW ×
two global batches through the fused sharded stack, plus the two-stage
re-warm-up run) and regression-gates a compact summary — steps-to-target,
target-reached flags, final losses, and the claim booleans — against
``scripts/baselines/convergence_baseline.json`` via ``RunReport.compare``.

Convergence quality is thereby a gated property, not a one-off plot: an
optimizer or schedule regression that slows the tiny study past tolerance
(or flips a claim) fails CI.  Tolerances are loose on anything float
(cross-platform drift); booleans and protocol constants are exact.
``--write-baseline`` refreshes the baseline after an intentional protocol
change; ``--from-json`` gates (or snapshots) an existing bench blob instead
of re-running the study.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "scripts" / "baselines" / "convergence_baseline.json"

# steps-to-target drifts with BLAS/jax versions (it is a loss-threshold
# crossing) — gate the shape, not the exact step: generous relative
# tolerances on numbers, exact equality on booleans / protocol constants.
# ``None`` entries (target unreached) go through compare's equality branch,
# so an unreached→reached flip fails the gate via ``target_reached``.
TOLERANCES = {
    "protocol.seq": 0.0,
    "protocol.tokens": 0.0,
    "protocol.target_loss": 0.0,
    "protocol.precision": 0.0,
    "protocol.mesh": 0.0,
    "protocol.batches": 0.0,
    "steps_to_target.lamb_b8": 0.5,
    "steps_to_target.lans_b8": 0.5,
    "steps_to_target.adamw_b8": 0.5,
    "target_reached.lamb_b8": 0.0,
    "target_reached.lamb_b64": 0.0,
    "target_reached.lans_b8": 0.0,
    "target_reached.lans_b64": 0.0,
    "target_reached.adamw_b8": 0.0,
    "target_reached.adamw_b64": 0.0,
    "final_loss.lamb_b8": 0.2,
    "final_loss.lamb_b64": 0.2,
    "final_loss.lans_b8": 0.2,
    "final_loss.lans_b64": 0.2,
    "final_loss.adamw_b8": 0.2,
    "final_loss.adamw_b64": 0.2,
    "claims.lamb_scales_no_worse_than_tuned_adamw": 0.0,
    "claims.rewarmup_stage2_improves": 0.0,
    "two_stage.lamb": 0.0,
    "two_stage.lans": 0.0,
}


def run_fast_bench(out: Path) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep + str(ROOT)
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, str(ROOT / "benchmarks" / "convergence_bench.py"),
           "--fast", "--out", str(out)]
    proc = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"convergence bench failed:\n{proc.stdout[-2000:]}\n"
            f"{proc.stderr[-2000:]}"
        )
    return json.loads(out.read_text())


def summarize(report: dict) -> dict:
    """The gated slice of a BENCH_convergence.json blob (no trajectories,
    no wall times — only what must stay stable across machines)."""
    s = {"protocol": {k: report["protocol"][k]
                      for k in ("seq", "tokens", "target_loss", "precision",
                                "mesh", "batches", "fast")},
         "steps_to_target": {}, "target_reached": {}, "final_loss": {}}
    for r in report["runs"]:
        key = f"{r['optimizer']}_b{r['batch']}"
        s["steps_to_target"][key] = r["steps_to_target"]
        s["target_reached"][key] = r["target_reached"]
        s["final_loss"][key] = r["train_loss"]
    s["claims"] = {k: v["holds"] for k, v in report["claims"].items()}
    s["two_stage"] = {opt: ts["stage2_improves"]
                      for opt, ts in report["two_stage"].items()}
    return s


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the committed baseline from this run")
    ap.add_argument("--from-json", default=None, metavar="PATH",
                    help="gate an existing bench JSON instead of re-running")
    args = ap.parse_args()

    sys.path.insert(0, str(ROOT / "src"))
    from repro.telemetry import RunReport

    if args.from_json:
        report = json.loads(Path(args.from_json).read_text())
    else:
        with tempfile.TemporaryDirectory() as d:
            report = run_fast_bench(Path(d) / "BENCH_convergence.json")
    summary = summarize(report)

    if args.write_baseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        BASELINE.write_text(json.dumps(summary, indent=2) + "\n")
        print(f"convergence_gate: baseline written -> {BASELINE}")
        return 0

    if not BASELINE.exists():
        print(f"convergence_gate: no baseline at {BASELINE}; "
              f"run with --write-baseline first", file=sys.stderr)
        return 2

    baseline = json.loads(BASELINE.read_text())
    result = RunReport(summary).compare(baseline, TOLERANCES)
    print(result.render())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
