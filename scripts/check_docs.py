#!/usr/bin/env python
"""Docs CI: validate internal links and run doctest-marked code fences.

    PYTHONPATH=src python scripts/check_docs.py [files...]

Defaults to README.md + docs/*.md.  Two checks:

  * every relative markdown link ``[text](path#anchor)`` resolves to an
    existing file (and, for .md targets, an existing ``#`` anchor);
  * every fenced code block whose info string contains ``doctest``
    (e.g. ```` ```python doctest ````) is executed with :mod:`doctest` —
    the fences in docs/ are living examples, not decoration.

Exit code 0 iff all links resolve and all doctests pass.
"""
from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\S[^\n]*)\n(.*?)^```\s*$", re.M | re.S)
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.M)


def _anchor(heading: str) -> str:
    """GitHub-style anchor slug for a heading."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\s-]", "", slug)
    return re.sub(r"\s+", "-", slug)


def _anchors(md_path: Path) -> set:
    return {_anchor(h) for h in HEADING_RE.findall(md_path.read_text())}


def check_links(md_path: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md_path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        resolved = (
            (md_path.parent / path_part).resolve() if path_part else md_path
        )
        if not resolved.exists():
            errors.append(f"{md_path}: broken link -> {target}")
            continue
        if anchor and resolved.suffix == ".md":
            if _anchor(anchor) not in _anchors(resolved):
                errors.append(f"{md_path}: missing anchor -> {target}")
    return errors


def check_doctests(md_path: Path) -> list:
    errors = []
    text = md_path.read_text()
    for i, m in enumerate(FENCE_RE.finditer(text)):
        info, body = m.group(1), m.group(2)
        if "doctest" not in info.split():
            continue
        runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS)
        test = doctest.DocTestParser().get_doctest(
            body, {}, f"{md_path.name}:fence{i}", str(md_path), 0
        )
        runner.run(test)
        if runner.failures:
            errors.append(
                f"{md_path}: doctest fence #{i} failed "
                f"({runner.failures}/{runner.tries} examples)"
            )
    return errors


def main(argv: list) -> int:
    files = (
        [Path(a) for a in argv]
        if argv
        else [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    )
    errors = []
    n_fences = 0
    for f in files:
        errors += check_links(f)
        n_fences += sum(
            1
            for m in FENCE_RE.finditer(f.read_text())
            if "doctest" in m.group(1).split()
        )
        errors += check_doctests(f)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(
        f"check_docs: {len(files)} files, {n_fences} doctest fences, "
        f"{len(errors)} errors"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
