#!/usr/bin/env python
"""CI telemetry gate: a 20-step tiny-BERT run with telemetry on, regression-
gated against the committed baseline report.

    PYTHONPATH=src python scripts/telemetry_gate.py            # gate
    PYTHONPATH=src python scripts/telemetry_gate.py --write-baseline

Runs ``repro.launch.train --smoke --telemetry-dir`` in a subprocess, then
``RunReport.compare`` against ``scripts/baselines/run_report_baseline.json``.
The tolerances are deliberately loose — this gates the telemetry *schema*
(sections present, counts exact, provenance populated), not machine speed:
timing keys are presence-only and the loss tolerance absorbs cross-platform
float drift.  ``--write-baseline`` refreshes the committed baseline after an
intentional schema change.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "scripts" / "baselines" / "run_report_baseline.json"

# schema + presence, not timing: exact where the run is deterministic by
# construction (step counts), loose on the loss, presence-only on anything
# machine- or checkout-dependent
TOLERANCES = {
    "schema_version": 0.0,
    "train.steps": 0.0,
    "train.logged_steps": 0.0,
    "train.examples_seen": 0.0,
    "train.final.loss/total": 0.25,
    "train.wall_s": None,
    "spans.step.count": 0.0,
    "spans.step.mean_s": None,
    "trust_ratios.steps_recorded": 0.0,
    "trust_ratios.last_step": 0.0,
    "trust_ratios.per_leaf.embed.mean": None,
    "events.count": 0.0,
    "events.types.run_start": 0.0,
    "events.types.step": 0.0,
    "events.types.span": 0.0,
    "events.types.trust_ratios": 0.0,
    "events.types.run_end": 0.0,
    "provenance.git_sha": None,
    "provenance.jax_version": None,
    "provenance.device_kind": None,
    "provenance.config_hash": None,
    "run_end.status": 0.0,
}


def run_tiny_fit(telemetry_dir: Path) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "bert-large", "--smoke",
        "--steps", "20", "--batch", "8", "--seq", "32", "--log-every", "5",
        "--fused-lamb", "--log-trust-ratios",
        "--telemetry-dir", str(telemetry_dir),
    ]
    proc = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"telemetry run failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
        )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the committed baseline from this run")
    args = ap.parse_args()

    sys.path.insert(0, str(ROOT / "src"))
    from repro.telemetry import RunReport

    with tempfile.TemporaryDirectory() as d:
        run_tiny_fit(Path(d))
        report = RunReport.load(Path(d) / "RUN_REPORT.json")
        events = (Path(d) / "events.jsonl").read_text()

    # the JSONL really is one valid event per line
    from repro.telemetry import validate_event

    for line in events.splitlines():
        validate_event(json.loads(line))

    if args.write_baseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        BASELINE.write_text(json.dumps(report.report, indent=2) + "\n")
        print(f"telemetry_gate: baseline written -> {BASELINE}")
        return 0

    if not BASELINE.exists():
        print(f"telemetry_gate: no baseline at {BASELINE}; "
              f"run with --write-baseline first", file=sys.stderr)
        return 2

    baseline = json.loads(BASELINE.read_text())
    result = report.compare(baseline, TOLERANCES)
    print(result.render())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
