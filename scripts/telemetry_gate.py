#!/usr/bin/env python
"""CI telemetry gate: a 20-step tiny-BERT run with telemetry on, regression-
gated against the committed baseline report.

    PYTHONPATH=src python scripts/telemetry_gate.py            # gate
    PYTHONPATH=src python scripts/telemetry_gate.py --write-baseline

Runs ``repro.launch.train --smoke --telemetry-dir`` in a subprocess — with
``--async-checkpoint`` on, so the ``checkpoint`` events (and their
snapshot/blocked/write timings from the double-buffered writer) are part of
the gated schema, and ``--skip-nonfinite`` on, so the in-jit non-finite
guard is live in the gated path (a clean run must skip zero steps and
report ``run_end.skipped_steps == 0``) — then ``RunReport.compare`` against
``scripts/baselines/run_report_baseline.json``.
The tolerances are deliberately loose — this gates the telemetry *schema*
(sections present, counts exact, provenance populated), not machine speed:
timing keys are presence-only and the loss tolerance absorbs cross-platform
float drift.  ``--write-baseline`` refreshes the committed baseline after an
intentional schema change.

On top of the schema compare, the gate asserts the async checkpointer
actually *overlapped* compute: background writes report nonzero wall time,
the loop-visible blocked time stays within a generous multiple of the
steady per-step time, and logged step times during in-flight saves stay
within tolerance of steady state.

The gate also runs a **serve** smoke: ``repro.launch.serve --continuous``
with the deterministic fault injector on (one transient NaN that retries to
success, one persistent slot corruption that exhausts its retry budget), and
compares its report against ``scripts/baselines/serve_report_baseline.json``
— terminal-state counts, retry/quarantine lifecycle counters and event
counts are exact (the injector is ordinal-keyed and the workload greedy, so
every replay must reproduce them bit-for-bit); latency/throughput keys are
presence-only.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE = ROOT / "scripts" / "baselines" / "run_report_baseline.json"
SERVE_BASELINE = ROOT / "scripts" / "baselines" / "serve_report_baseline.json"

# schema + presence, not timing: exact where the run is deterministic by
# construction (step counts), loose on the loss, presence-only on anything
# machine- or checkout-dependent
TOLERANCES = {
    "schema_version": 0.0,
    "train.steps": 0.0,
    "train.logged_steps": 0.0,
    "train.examples_seen": 0.0,
    "train.final.loss/total": 0.25,
    "train.wall_s": None,
    "spans.step.count": 0.0,
    "spans.step.mean_s": None,
    "trust_ratios.steps_recorded": 0.0,
    "trust_ratios.last_step": 0.0,
    "trust_ratios.per_leaf.embed.mean": None,
    "checkpoints.count": 0.0,
    "checkpoints.last_step": 0.0,
    "checkpoints.async.count": 0.0,
    "checkpoints.async.snapshot_s_mean": None,
    "checkpoints.async.blocked_s_mean": None,
    "checkpoints.async.write_s_mean": None,
    "events.count": 0.0,
    "events.types.run_start": 0.0,
    "events.types.step": 0.0,
    "events.types.span": 0.0,
    "events.types.trust_ratios": 0.0,
    "events.types.checkpoint": 0.0,
    "events.types.run_end": 0.0,
    "provenance.git_sha": None,
    "provenance.jax_version": None,
    "provenance.device_kind": None,
    "provenance.config_hash": None,
    "run_end.status": 0.0,
    "run_end.final_step": 0.0,
    "run_end.skipped_steps": 0.0,
    "run_end.final_loss": 0.25,
    "status": 0.0,
}

# serve smoke: the fault injector is ordinal-keyed and the workload greedy,
# so terminal-state counts and lifecycle counters are exact on every replay;
# latencies/throughput are machine speed and stay presence-only
SERVE_TOLERANCES = {
    "schema_version": 0.0,
    "serve.requests": 0.0,
    "serve.dropped": 0.0,
    "serve.by_status.completed": 0.0,
    "serve.by_status.shed": 0.0,
    "serve.by_status.timed_out": 0.0,
    "serve.by_status.failed": 0.0,
    "serve.lifecycle.retries": 0.0,
    "serve.lifecycle.quarantines": 0.0,
    "serve.lifecycle.sheds": 0.0,
    "serve.lifecycle.timeouts": 0.0,
    "serve.lifecycle.drains": 0.0,
    "serve.stats.submitted": 0.0,
    "serve.stats.completed": 0.0,
    "serve.stats.failed": 0.0,
    "serve.stats.tokens_per_s": None,
    "serve.stats.latency_p99_s": None,
    "serve.stats.ttft_p50_s": None,
    "events.types.serve_request": 0.0,
    "events.types.serve_retry": 0.0,
    "events.types.serve_quarantine": 0.0,
    "events.types.serve_stats": 0.0,
    "provenance.git_sha": None,
    "provenance.jax_version": None,
    "status": 0.0,
}


def run_tiny_fit(telemetry_dir: Path, checkpoint_dir: Path) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "bert-large", "--smoke",
        "--steps", "20", "--batch", "8", "--seq", "32", "--log-every", "5",
        "--fused-lamb", "--log-trust-ratios",
        "--telemetry-dir", str(telemetry_dir),
        # async double-buffered saves: checkpoint events (with
        # snapshot/blocked/write timings) become part of the gated schema
        "--checkpoint-dir", str(checkpoint_dir), "--checkpoint-every", "5",
        "--async-checkpoint",
        # guard-enabled smoke: the non-finite skip-step select is compiled
        # into the gated step function; a clean run must skip nothing
        "--skip-nonfinite",
    ]
    proc = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"telemetry run failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
        )


def run_serve_smoke(telemetry_dir: Path) -> None:
    """Continuous-batching serve smoke with deterministic faults: rid 1
    hits one transient NaN (retry succeeds), rid 2 hits persistent slot
    corruption (the --retries 1 budget exhausts -> FAILED).  Closed greedy
    workload, so the terminal counts replay exactly."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "smollm-360m", "--smoke", "--continuous",
        "--requests", "6", "--slots", "4",
        "--prompt-len", "8", "--max-new", "8",
        "--arrival-rate", "0", "--retries", "1",
        "--inject-faults", "sample_nan@1,slot_corrupt@2:persist",
        "--telemetry-dir", str(telemetry_dir),
    ]
    proc = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"serve smoke failed:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
        )


def check_async_overlap(events: list) -> list:
    """Assert the async checkpointer overlapped compute (from raw events).

    Returns a list of error strings (empty = pass).  Bounds are generous —
    this catches "saves serialize the loop", not machine speed: the
    loop-visible blocked time and the logged step times during in-flight
    saves must stay within a multiple of the steady per-step time (estimated
    as the fastest post-compile logged interval) plus absolute slack.
    """
    errors = []
    asyncs = [e for e in events
              if e["event"] == "checkpoint" and e.get("mode") == "async"]
    if not asyncs:
        return ["no async checkpoint events in the smoke run"]
    for ev in asyncs:
        if not ev.get("write_s", 0.0) > 0.0:
            errors.append(f"checkpoint step {ev['step']}: no background "
                          f"write timing (write_s={ev.get('write_s')!r})")
    per = [e["step_time_s"] for e in events
           if e["event"] == "step" and "step_time_s" in e]
    if len(per) < 2:
        return errors + ["too few step_time_s intervals to judge overlap"]
    steady = min(per[1:])  # interval 1 pays jit compilation
    bound = max(5.0 * steady, 0.25)
    for ev in asyncs:
        if ev["blocked_s"] > bound:
            errors.append(
                f"checkpoint step {ev['step']}: blocked_s={ev['blocked_s']:.3f}"
                f" exceeds {bound:.3f} (5x steady {steady:.3f}s) — the save"
                f" is not overlapping the previous write")
    worst = max(per[1:])
    if worst > 5.0 * steady + 0.25:
        errors.append(
            f"step time during in-flight saves ({worst:.3f}s) not within "
            f"tolerance of steady state ({steady:.3f}s) — saves are "
            f"stalling the loop")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-baseline", action="store_true",
                    help="refresh the committed baseline from this run")
    args = ap.parse_args()

    sys.path.insert(0, str(ROOT / "src"))
    from repro.telemetry import RunReport

    with tempfile.TemporaryDirectory() as d:
        run_tiny_fit(Path(d) / "telemetry", Path(d) / "ckpt")
        report = RunReport.load(Path(d) / "telemetry" / "RUN_REPORT.json")
        events_text = (Path(d) / "telemetry" / "events.jsonl").read_text()
        run_serve_smoke(Path(d) / "serve_telemetry")
        serve_report = RunReport.load(
            Path(d) / "serve_telemetry" / "RUN_REPORT.json")

    # the JSONL really is one valid event per line
    from repro.telemetry import validate_event

    events = []
    for line in events_text.splitlines():
        ev = json.loads(line)
        validate_event(ev)
        events.append(ev)

    # async saves must actually overlap compute, baseline or not
    overlap_errors = check_async_overlap(events)
    for e in overlap_errors:
        print(f"telemetry_gate: overlap: {e}", file=sys.stderr)
    if overlap_errors:
        return 1

    if args.write_baseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        BASELINE.write_text(json.dumps(report.report, indent=2) + "\n")
        SERVE_BASELINE.write_text(
            json.dumps(serve_report.report, indent=2) + "\n")
        print(f"telemetry_gate: baselines written -> {BASELINE}, "
              f"{SERVE_BASELINE}")
        return 0

    for p in (BASELINE, SERVE_BASELINE):
        if not p.exists():
            print(f"telemetry_gate: no baseline at {p}; "
                  f"run with --write-baseline first", file=sys.stderr)
            return 2

    baseline = json.loads(BASELINE.read_text())
    result = report.compare(baseline, TOLERANCES)
    print(result.render())
    serve_result = serve_report.compare(
        json.loads(SERVE_BASELINE.read_text()), SERVE_TOLERANCES)
    print(serve_result.render())
    return 0 if (result.ok and serve_result.ok) else 1


if __name__ == "__main__":
    sys.exit(main())
