#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a fast serving smoke + docs checks.
#
#   scripts/ci.sh          # full tier-1 (includes the slow dry-run test)
#   CI_FAST=1 scripts/ci.sh  # skip the slow production dry-run subprocess
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# fast-fail kernel gate: interpret-mode flash fwd+bwd gradient equivalence
# (the Pallas kernels run under the interpreter here, so a backward-kernel
# regression fails CI on CPU in under a minute; the full suite below covers
# the xla backend and the rest of the flash matrix)
python -m pytest -q tests/test_kernels.py -k "flash_grad and interpret"

# fast-fail fused-CE gate: interpret-mode chunked-vocab CE gradients vs the
# dense oracle (same pattern as the flash gate; the full fused-head matrix
# — backends × precision × supervision — runs in the suite below)
python -m pytest -q tests/test_fused_ce.py -k "grad and interpret"

# fast-fail checkpoint gate: atomic-write crash consistency, async
# double-buffered checkpointer overlap, and full-state resume bit-exactness
# in-process (the SIGKILL preemption suite rides in test_sharded_train.py)
python -m pytest -q tests/test_checkpoint.py

# fast-fail fault-tolerance gate: spike-detector properties, in-jit skip-step
# state identity, and fault-injector determinism — the cheap single-device
# slice of the robustness suite (trainer rollback/preemption integration and
# the multi-device nan_skip/spike_rollback/sigterm_resume scenarios run in
# the full suite and test_sharded_train.py below)
python -m pytest -q tests/test_fault_tolerance.py -k "detector or injector or skip_step"

# fast-fail serve fault-injection gate: the serving reliability layer's
# deterministic scenarios — retries, quarantine, timeout-frees-slot, drain
# under load, and the every-request-one-terminal-state invariant
python -m pytest -q tests/test_serve_faults.py

# multi-device gate: sharded train step ≡ single-device on 8 virtual CPU
# devices (the harness subprocess sets --xla_force_host_platform_device_count
# before jax init — the flag is dead after backend init, same constraint as
# the production dry-run).  Skipped under CI_FAST: the dedicated
# `multidevice` workflow job runs exactly this suite.
if [[ -z "${CI_FAST:-}" ]]; then
  python -m pytest -q tests/test_sharded_train.py
fi

if [[ -n "${CI_FAST:-}" ]]; then
  python -m pytest -x -q -m "not slow" --ignore=tests/test_sharded_train.py
else
  python -m pytest -x -q --ignore=tests/test_sharded_train.py
fi

# continuous-batching serving smoke: tiny workload, must stream and drain
python examples/serve_continuous.py --requests 4 --slots 2 --arrival-rate 50

# serving reliability scenarios: capacity vs 2x-overload (admission control
# must shed explicitly, hold admitted-request p99 within the structural SLO
# bound and keep goodput >= 80% of capacity) plus the deterministic fault
# replay — the run() claims raise on any violation.  Skipped under CI_FAST
# (one jit warmup + three serving phases): the benchmarks workflow and the
# full local gate run it.
if [[ -z "${CI_FAST:-}" ]]; then
  python benchmarks/serve_bench.py --scenarios --fast
fi

# convergence gate: the fast-tier batch-scaling study (LAMB / LANS / tuned
# AdamW through the fused sharded stack + the two-stage re-warm-up run)
# regression-gated against scripts/baselines/convergence_baseline.json —
# steps-to-target, target-reached flags, final losses, claim booleans.
# Skipped under CI_FAST (several CPU-minutes of training): the dedicated
# `convergence` workflow job runs exactly this gate.
if [[ -z "${CI_FAST:-}" ]]; then
  python scripts/convergence_gate.py
fi

# telemetry gate: 20-step tiny-BERT fit with the event log AND async
# double-buffered checkpointing on, RUN_REPORT compared against the
# committed baseline (schema + presence, not timing) plus an overlap check
# on the checkpoint events (background writes must not stall the loop)
python scripts/telemetry_gate.py

# docs: internal links + doctest-marked code fences in README.md and docs/
# (also run standalone by the ci.yml `docs` job for fast-fail signal; here it
# keeps this script the complete local gate)
python scripts/check_docs.py

echo "ci.sh: OK"
