#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a fast serving smoke + docs checks.
#
#   scripts/ci.sh          # full tier-1 (includes the slow dry-run test)
#   CI_FAST=1 scripts/ci.sh  # skip the slow production dry-run subprocess
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# fast-fail kernel gate: interpret-mode flash fwd+bwd gradient equivalence
# (the Pallas kernels run under the interpreter here, so a backward-kernel
# regression fails CI on CPU in under a minute; the full suite below covers
# the xla backend and the rest of the flash matrix)
python -m pytest -q tests/test_kernels.py -k "flash_grad and interpret"

if [[ -n "${CI_FAST:-}" ]]; then
  python -m pytest -x -q -m "not slow"
else
  python -m pytest -x -q
fi

# continuous-batching serving smoke: tiny workload, must stream and drain
python examples/serve_continuous.py --requests 4 --slots 2 --arrival-rate 50

# docs: internal links + doctest-marked code fences in README.md and docs/
# (also run standalone by the ci.yml `docs` job for fast-fail signal; here it
# keeps this script the complete local gate)
python scripts/check_docs.py

echo "ci.sh: OK"
