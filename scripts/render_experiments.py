"""Render EXPERIMENTS.md tables from the dry-run / hillclimb JSONL files.

    PYTHONPATH=src python scripts/render_experiments.py [--section all]

Prints markdown; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results")


def load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    # last record wins per key
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""),
             r.get("optimizer", ""))] = r
    return list(out.values())


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def dryrun_table(recs, mesh):
    rows = [
        "| arch | shape | status | compile s | args GB/dev | temp GB/dev | "
        "HLO GFLOP/dev | HLO GB/dev | coll GB/dev | cost src |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP: {r['note'][:40]} "
                        "| - | - | - | - | - | - | - |")
            continue
        m, rl = r["memory"], r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
            f"| {fmt_bytes(m.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(m.get('temp_size_in_bytes'))} "
            f"| {rl['flops']/1e9:.0f} | {rl['hbm_bytes']/1e9:.1f} "
            f"| {rl['coll_bytes']/1e9:.3f} "
            f"| {r.get('cost_source', '?').split(' ')[0]} |"
        )
    return "\n".join(rows)


def roofline_table(recs):
    rows = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant | "
        "MODEL_FLOPS/HLO | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    LEVERS = {
        ("memory", "train"): "remat/flash-attn (stop materializing S² probs + per-layer stash)",
        ("memory", "prefill"): "flash-attention tiling; shard attention temps",
        ("memory", "decode"): "shard cache seq axis over model; bf16 serving params",
        ("compute", "train"): "MoE dispatch dedup; fewer recompute passes",
        ("compute", "prefill"): "SWA/block-sparse attention to cut S² FLOPs",
        ("compute", "decode"): "absorbed MLA / smaller per-token reconstruct",
        ("collective", "train"): "1-axis FSDP or TP-only weights; overlap all-gather",
        ("collective", "prefill"): "reduce activation resharding between layers",
        ("collective", "decode"): "keep cache+weights co-sharded; avoid re-gather",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "1pod" or r.get("status") != "ok":
            continue
        rl = r["roofline"]
        kind = ("train" if r["shape"].startswith("train")
                else "prefill" if "prefill" in r["shape"] else "decode")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.1f} "
            f"| {rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} "
            f"| **{rl['dominant']}** | {rl['useful_fraction']:.3f} "
            f"| {LEVERS[(rl['dominant'], kind)]} |"
        )
    return "\n".join(rows)


def hillclimb_table(recs):
    rows = [
        "| tag | overrides | compute ms | memory ms | coll ms | args GB | "
        "temp GB | HLO GB | coll MB | cost src |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: r.get("tag", "")):
        if r.get("status") != "ok":
            continue
        m, rl = r["memory"], r["roofline"]
        ov = " ".join(r.get("overrides", []) + r.get("param_rules", [])
                      + r.get("act_rules", []))
        if r.get("moment_dtype"):
            ov += f" m/v={r['moment_dtype']}"
        rows.append(
            f"| {r['tag']} | {ov or '(baseline)'} | {rl['compute_s']*1e3:.1f} "
            f"| {rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.2f} "
            f"| {fmt_bytes(m.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(m.get('temp_size_in_bytes'))} "
            f"| {rl['hbm_bytes']/1e9:.1f} | {rl['coll_bytes']/1e6:.1f} "
            f"| {r.get('cost_source', '?').split(' ')[0]} |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument("--write", action="store_true",
                    help="splice tables into EXPERIMENTS.md")
    args = ap.parse_args()
    if args.write:
        splice_into_experiments()
        return
    base = load("dryrun_baseline_v2.jsonl")
    hill = load("hillclimb.jsonl")

    if args.section in ("all", "dryrun"):
        print("### Single-pod (16×16 = 256 chips)\n")
        print(dryrun_table(base, "1pod"))
        print("\n### Multi-pod (2×16×16 = 512 chips)\n")
        print(dryrun_table(base, "2pod"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline terms (single-pod)\n")
        print(roofline_table(base))
    if args.section in ("all", "hillclimb"):
        print("\n### Hillclimb runs\n")
        print(hillclimb_table(hill))


def splice_into_experiments():
    """Replace the BEGIN/END GENERATED blocks in EXPERIMENTS.md in place."""
    import re

    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    base = load("dryrun_baseline_v2.jsonl")
    hill = load("hillclimb.jsonl")
    blocks = {
        "dryrun": (
            "### Single-pod (16×16 = 256 chips)\n\n"
            + dryrun_table(base, "1pod")
            + "\n\n### Multi-pod (2×16×16 = 512 chips)\n\n"
            + dryrun_table(base, "2pod")
        ),
        "roofline": roofline_table(base),
        "hillclimb": hillclimb_table(hill),
    }
    text = open(path).read()
    for key, content in blocks.items():
        pattern = re.compile(
            rf"<!-- BEGIN GENERATED: {key} -->.*?<!-- END GENERATED -->",
            re.DOTALL,
        )
        text = pattern.sub(
            f"<!-- BEGIN GENERATED: {key} -->\n{content}\n<!-- END GENERATED -->",
            text,
        )
    open(path, "w").write(text)
    print(f"spliced {len(blocks)} blocks into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
