"""Quickstart: train a small LM with LAMB using the paper's untuned recipe.

    PYTHONPATH=src python examples/quickstart.py [--steps 60]

Builds a reduced SmolLM-family model, derives the LR from the sqrt-scaling
rule and the warmup from linear-epoch scaling (§4.3), trains on the synthetic
corpus, and prints the loss curve + per-layer trust-ratio summary.
"""
import argparse

from repro import core
from repro.configs import smoke_config
from repro.configs.base import TrainConfig
from repro.data import DataPipeline
from repro.models import build_model
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = smoke_config("smollm-360m").replace(n_layers=4, d_model=256)
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={model.param_count()/1e6:.2f}M")

    # the paper's untuned recipe, scaled from a base batch of 16
    lr = core.sqrt_scaled_lr(2.5e-3, 16, args.batch)
    warmup_ratio = core.linear_epoch_warmup_ratio(1 / 40, 16, args.batch)
    sched = core.warmup_poly_decay(
        lr, args.steps, max(int(args.steps * warmup_ratio), 1))

    tc = TrainConfig(optimizer="lamb", learning_rate=lr, log_trust_ratios=True)
    trainer = Trainer(model, tc, schedule=sched, log_every=10)
    data = DataPipeline(cfg, args.batch, args.seq, seed=0)
    hist = trainer.fit(data, args.steps)

    last = hist[-1]
    print(f"\nfinal: loss={last['loss/total']:.4f} acc={last['accuracy']:.4f}")
    print(f"trust ratios: min={last['trust_ratio/min']:.3f} "
          f"mean={last['trust_ratio/mean']:.3f} max={last['trust_ratio/max']:.3f}")


if __name__ == "__main__":
    main()
