"""End-to-end driver: the paper's mixed-batch BERT recipe (§4.1), CPU-scaled.

    PYTHONPATH=src python examples/mixed_batch_bert.py [--scale 64]

Trains a BERT-family MLM encoder through BOTH stages of the 76-minute recipe
— stage 1 at short sequences / large batch, stage 2 at 4x sequence length /
smaller batch with LR re-warm-up — exactly the paper's procedure with every
size divided by --scale.  A few hundred steps of a ~10M model by default.
"""
import argparse

from repro import core
from repro.configs.base import TrainConfig
from repro.configs.bert_large import tiny
from repro.models import build_model
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=2048,
                    help="divide the paper's batch sizes by this")
    ap.add_argument("--step-scale", type=int, default=32,
                    help="divide the paper's step counts by this")
    args = ap.parse_args()

    cfg = tiny(vocab=2048)
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={model.param_count()/1e6:.2f}M")

    plan = core.bert_mixed_batch_plan(
        seq1=32, seq2=128,                    # paper: 128 → 512
        batch1=max(65536 // args.scale, 2),
        batch2=max(32768 // args.scale, 1),
        steps1=max(7038 // args.step_scale, 4),
        steps2=max(1561 // args.step_scale, 2),
    )
    for s in plan:
        print(f"  stage {s.name}: seq={s.seq_len} batch={s.batch_size} "
              f"steps={s.steps} lr={s.learning_rate:.2e} "
              f"rewarmup={s.warmup_steps} steps")

    tc = TrainConfig(optimizer="lamb", learning_rate=plan[0].learning_rate)
    trainer = Trainer(model, tc, log_every=20)
    hist = trainer.fit_stages(plan)
    s2 = [h for h in hist if h.get("stage") == 1]
    print(f"\nstage-2 final loss {s2[-1]['loss/total']:.4f} "
          f"(stage switch survived re-warm-up: "
          f"{all(h['loss/total'] < 50 for h in s2)})")


if __name__ == "__main__":
    main()
