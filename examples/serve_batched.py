"""Batched serving example: prefill + greedy decode over a request batch.

    PYTHONPATH=src python examples/serve_batched.py [--arch smollm-360m]

Uses the reduced config of the chosen architecture (any decoder family:
dense / MoE / MLA / hybrid / xLSTM) and reports tokens/s.
"""
import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.name} ({model.param_count()/1e6:.2f}M params)")

    eng = Engine(model, params, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, 256, size=12).astype(np.int32),
                max_new_tokens=args.max_new)
        for _ in range(args.requests)
    ]
    out = eng.generate_batch(reqs)
    for i, r in enumerate(out):
        print(f"req[{i}]: {r.out_tokens[:12]} ...")
    print("stats:", eng.throughput_stats(out))


if __name__ == "__main__":
    main()
