"""LAMB vs Adam/AdamW/LARS/momentum at growing batch size (Tables 2-3 shape).

    PYTHONPATH=src python examples/optimizer_comparison.py [--batches 8,32]

Fixed token budget: larger batch = proportionally fewer steps.  LAMB uses the
untuned recipe; baselines use a reasonable fixed LR.  Prints a table of final
eval loss per (optimizer, batch).
"""
import argparse

from repro import core
from benchmarks.common import bert_cpu, fixed_epoch_steps, train_once

BASE = {"lamb": 2.5e-3, "adamw": 1e-3, "adam": 1e-3, "lars": 1.0,
        "momentum": 1e-1}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", default="8,32")
    ap.add_argument("--tokens", type=int, default=16 * 64 * 80)
    args = ap.parse_args()
    batches = [int(b) for b in args.batches.split(",")]

    cfg = bert_cpu()
    print(f"{'optimizer':10s} " + " ".join(f"batch={b:<6d}" for b in batches))
    for opt, base_lr in BASE.items():
        row = []
        for b in batches:
            steps = fixed_epoch_steps(args.tokens, b, 64)
            lr = core.sqrt_scaled_lr(base_lr, 16, b)
            out = train_once(cfg, optimizer=opt, batch=b, seq=64,
                             steps=steps, lr=lr, warmup_ratio=0.1)
            row.append(out["eval_loss"])
        print(f"{opt:10s} " + " ".join(f"{v:<12.4f}" for v in row))


if __name__ == "__main__":
    main()
