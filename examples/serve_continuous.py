"""Continuous-batching serving example: slot pool + streaming callbacks.

    PYTHONPATH=src python examples/serve_continuous.py [--arch smollm-360m]

Requests with mixed prompt lengths, generation budgets, and temperatures
arrive over a Poisson process; the engine keeps a fixed-shape decode batch
full by swapping finished slots for queued requests between steps, streaming
each token to a callback as it is sampled.
"""
import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    FCFSScheduler,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    serving_stats,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--arrival-rate", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"serving {cfg.name} ({model.param_count()/1e6:.2f}M params) "
          f"on {args.slots} slots")

    rng = np.random.default_rng(args.seed)
    reqs = [
        ServeRequest(
            prompt=rng.integers(0, 256, size=int(rng.integers(6, 14))).astype(
                np.int32),
            max_new_tokens=int(rng.integers(4, 12)),
            temperature=float(rng.choice([0.0, 0.8])),
        )
        for _ in range(args.requests)
    ]
    assign_arrivals(
        reqs, poisson_arrivals(len(reqs), args.arrival_rate, seed=args.seed))

    streamed = {}

    def on_token(req, tok):
        streamed.setdefault(req.rid, []).append(tok)

    eng = ContinuousEngine(
        model, params, n_slots=args.slots,
        max_len=32, seed=args.seed, scheduler=FCFSScheduler(),
    )
    out = eng.generate(reqs, on_token=on_token)

    for r in out:
        assert streamed[r.rid] == r.out_tokens  # stream == final output
        print(f"req[{r.rid}] prompt={len(r.prompt):2d} "
              f"new={len(r.out_tokens):2d} temp={r.temperature:.1f} "
              f"ttft={r.ttft_s*1e3:6.1f}ms lat={r.latency_s*1e3:6.1f}ms "
              f"-> {np.asarray(r.out_tokens[:8])}")
    print("stats:", serving_stats(out))


if __name__ == "__main__":
    main()
