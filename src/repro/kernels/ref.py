"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def lamb_update_ref(
    x: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    step: int = 1,
    phi_bounds: Optional[Tuple[float, float]] = None,
    layer_axis: Optional[int] = None,
    apply_trust: bool = True,
    return_ratio: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """One LAMB step on a single tensor.  Returns (x', m', v').

    layer_axis: stacked-layers axis → per-slice trust ratios (scan-aware).
    ``lr`` and ``step`` may be traced scalars (schedules inside jit) — this
    is the XLA fallback backend of ``kernels.ops.fused_lamb``, not just a
    test oracle.  ``return_ratio=True`` appends the applied per-layer trust
    ratio (pre-lr, squeezed to a vector/scalar) — same aux contract as the
    Pallas kernel's.
    """
    x32, g32 = x.astype(jnp.float32), g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * g32 * g32
    t = jnp.asarray(step, jnp.float32)
    c1 = 1.0 / (1.0 - b1**t)
    c2 = 1.0 / (1.0 - b2**t)
    r = (m_new * c1) / (jnp.sqrt(v_new * c2) + eps)
    u = r + weight_decay * x32

    if layer_axis is None or layer_axis < 0:
        axes = tuple(range(x.ndim))
        keep = False
    else:
        axes = tuple(i for i in range(x.ndim) if i != layer_axis)
        keep = True
    w_norm = jnp.sqrt(jnp.sum(x32 * x32, axis=axes, keepdims=keep))
    u_norm = jnp.sqrt(jnp.sum(u * u, axis=axes, keepdims=keep))
    if phi_bounds is not None:
        w_norm = jnp.clip(w_norm, phi_bounds[0], phi_bounds[1])
    ratio = jnp.where(w_norm > 0, jnp.where(u_norm > 0, w_norm / u_norm, 1.0), 1.0)
    if not apply_trust:
        ratio = jnp.ones_like(ratio)
    x_new = x32 - lr * ratio * u
    out = (x_new.astype(x.dtype), m_new, v_new)
    if return_ratio:
        out += (jnp.squeeze(ratio),)
    return out


def lans_update_ref(
    x: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    *,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    step: int = 1,
    phi_bounds: Optional[Tuple[float, float]] = None,
    layer_axis: Optional[int] = None,
    apply_trust: bool = True,
    return_ratio: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """One LANS step on a single tensor as one fused XLA expression.

    Zheng et al.'s update: the gradient is block-normalized (per layer
    slice when ``layer_axis`` is set) *before* the Adam moments, and the
    step mixes the momentum direction ``d = m̂/(√v̂+ε) + λx`` with the
    current-gradient direction ``d' = g̃/(√v̂+ε) + λx``, each scaled by its
    own trust ratio:  x' = x − η·[β1·r(d)·d + (1−β1)·r(d')·d'].

    Returns (x', m', v').  Serves both as the numpy-style oracle the unit
    tests check ``core.lans`` against and as the fused-XLA expression of
    the same math (same contract as ``lamb_update_ref``).
    ``return_ratio=True`` appends the momentum-term trust ratio (squeezed).
    """
    x32, g32 = x.astype(jnp.float32), g.astype(jnp.float32)
    if layer_axis is None or layer_axis < 0:
        axes = tuple(range(x.ndim))
        keep = False
    else:
        axes = tuple(i for i in range(x.ndim) if i != layer_axis)
        keep = True

    def norm(a):
        return jnp.sqrt(jnp.sum(a * a, axis=axes, keepdims=keep))

    gn = norm(g32)
    g_t = jnp.where(gn > 0, g32 / jnp.where(gn > 0, gn, 1.0), g32)
    m_new = b1 * m + (1 - b1) * g_t
    v_new = b2 * v + (1 - b2) * g_t * g_t
    t = jnp.asarray(step, jnp.float32)
    denom = jnp.sqrt(v_new / (1.0 - b2**t)) + eps
    wd = weight_decay * x32
    d_m = m_new / (1.0 - b1**t) / denom + wd
    d_g = g_t / denom + wd

    w_norm = norm(x32)
    if phi_bounds is not None:
        w_norm = jnp.clip(w_norm, phi_bounds[0], phi_bounds[1])

    def ratio(u):
        un = norm(u)
        return jnp.where(w_norm > 0, jnp.where(un > 0, w_norm / un, 1.0), 1.0)

    r_m = ratio(d_m) if apply_trust else jnp.ones_like(w_norm)
    r_g = ratio(d_g) if apply_trust else jnp.ones_like(w_norm)
    x_new = x32 - lr * (b1 * r_m * d_m + (1 - b1) * r_g * d_g)
    out = (x_new.astype(x.dtype), m_new, v_new)
    if return_ratio:
        out += (jnp.squeeze(r_m),)
    return out


def flash_attention_ref(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, T, D)
    v: jnp.ndarray,  # (B, H, T, D)
    kv_valid: Optional[jnp.ndarray] = None,  # (B,) valid kv lengths
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    window: int = 0,
) -> jnp.ndarray:
    """Dense-softmax oracle for the flash kernel (differentiable; the
    allclose target for both outputs and ``jax.grad`` cotangents)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * scale
    sq, tk = q.shape[2], k.shape[2]
    rows = jnp.arange(sq)[:, None] + (tk - sq)
    cols = jnp.arange(tk)[None, :]
    mask = jnp.ones((sq, tk), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    mask = jnp.broadcast_to(mask, (q.shape[0], 1, sq, tk))
    if kv_valid is not None:
        valid = jnp.clip(kv_valid.astype(jnp.int32), 1, tk)
        mask &= cols[None, None] < valid[:, None, None, None]
    if causal or window or kv_valid is not None:
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v)


def fused_ce_ref(
    h: jnp.ndarray,        # (N, D)
    w: jnp.ndarray,        # (V, D)
    labels: jnp.ndarray,   # (N,) int in [0, V)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense oracle for ``kernels.fused_ce``: full (N, V) fp32 logits.

    Returns per-row ``(nll, correct)`` — the allclose target for both the
    chunked outputs and their ``jax.grad`` cotangents (w.r.t. h and w).
    """
    logits = jnp.einsum(
        "nd,vd->nv", h.astype(jnp.float32), w.astype(jnp.float32)
    )
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return lse - ll, correct
