"""Fused LAMB update — Pallas TPU kernel.

The optimizer step is HBM-bandwidth bound: naively expressed in XLA it makes
~11 full passes over model-sized arrays (m/v EMA updates, bias correction,
ratio, weight decay, two norm reductions, apply), and the global norm
reductions split the fusion.  This kernel does it in two structured passes of
VPU-aligned (1, BLOCK) tiles over the flattened (layers, P) view:

  pass A (``_moments_kernel``): read g, x, m, v → write m', v' and per-block
      partial sums of ‖x‖² and ‖u‖² (u = r + wd·x recomputed from m', v').
  (host) per-layer trust ratio = phi(‖x‖)/‖u‖.
  pass B (``_apply_kernel``): read x, m', v' + ratio → write x' (u recomputed;
      cheaper than writing a param-sized u temp in pass A).

Total traffic ≈ 10 N  vs ≈ 21 N unfused.  The stacked-layers axis is grid
dim 0, giving exact per-layer (scan-aware) trust ratios.  Padding tokens are
zeros in all four arrays, making every derived quantity zero — no masks.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 1024  # lanes-aligned (8·128 | 8192 f32 = 32 KiB / operand)


def _moments_kernel(
    c_ref, x_ref, g_ref, m_ref, v_ref,
    m_out, v_out, xsq_out, usq_out,
    *, b1: float, b2: float, eps: float, wd: float,
):
    c1 = c_ref[0, 0]
    c2 = c_ref[0, 1]
    g = g_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    m_out[...] = m_new
    v_out[...] = v_new
    r = (m_new * c1) / (jnp.sqrt(v_new * c2) + eps)
    u = r + wd * x
    xsq_out[0, 0] = jnp.sum(x * x)
    usq_out[0, 0] = jnp.sum(u * u)


def _apply_kernel(
    c_ref, ratio_ref, x_ref, m_ref, v_ref, x_out,
    *, eps: float, wd: float, lr: float,
):
    c1 = c_ref[0, 0]
    c2 = c_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    r = (m_ref[...] * c1) / (jnp.sqrt(v_ref[...] * c2) + eps)
    u = r + wd * x
    x_out[...] = (x - lr * ratio_ref[0, 0] * u).astype(x_out.dtype)


def _pad_flat(a: jnp.ndarray, layers: int, p_pad: int) -> jnp.ndarray:
    flat = a.reshape(layers, -1)
    pad = p_pad - flat.shape[1]
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat


@functools.partial(
    jax.jit,
    static_argnames=(
        "b1", "b2", "eps", "weight_decay", "lr", "phi_lo", "phi_hi",
        "layer_axis", "block", "interpret", "apply_trust", "return_ratio",
    ),
)
def lamb_update(
    x: jnp.ndarray,
    g: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    step: jnp.ndarray,
    lr_t: Optional[jnp.ndarray] = None,  # traced LR (schedules); multiplies `lr`
    *,
    lr: float = 1.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    phi_lo: Optional[float] = None,
    phi_hi: Optional[float] = None,
    layer_axis: Optional[int] = None,
    apply_trust: bool = True,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
    return_ratio: bool = False,
) -> Tuple[jnp.ndarray, ...]:
    """Fused LAMB step on one tensor.  Returns (x', m', v').

    ``step`` is the 1-based iteration (traced scalar); betas/lr are static.
    ``layer_axis`` must be 0 or None (stacks put layers first by convention).
    ``return_ratio=True`` appends the applied per-layer trust ratio — the
    exact phi(‖x‖)/‖u‖ the kernel scaled by, *before* the lr fold-in — as a
    fourth output (shape ``(layers,)``; the telemetry recorder's aux).
    """
    if layer_axis not in (None, -1, 0):
        raise ValueError("lamb_update supports layer_axis in {None, 0}")
    stacked = layer_axis == 0
    layers = x.shape[0] if stacked else 1
    per_layer = x.size // layers
    blk = min(block, max(pl.next_power_of_2(per_layer), 128))
    p_pad = pl.cdiv(per_layer, blk) * blk
    nb = p_pad // blk

    orig_shape, orig_dtype = x.shape, x.dtype
    xf = _pad_flat(x, layers, p_pad)
    gf = _pad_flat(g, layers, p_pad)
    mf = _pad_flat(m.astype(jnp.float32), layers, p_pad)
    vf = _pad_flat(v.astype(jnp.float32), layers, p_pad)

    t = step.astype(jnp.float32)
    c = jnp.stack([1.0 / (1.0 - b1**t), 1.0 / (1.0 - b2**t)]).reshape(1, 2)

    tile = pl.BlockSpec((1, blk), lambda l, i: (l, i))
    cell = pl.BlockSpec((1, 1), lambda l, i: (l, i))
    scal = pl.BlockSpec((1, 2), lambda l, i: (0, 0))

    m_new, v_new, xsq, usq = pl.pallas_call(
        functools.partial(
            _moments_kernel, b1=b1, b2=b2, eps=eps, wd=weight_decay
        ),
        grid=(layers, nb),
        in_specs=[scal, tile, tile, tile, tile],
        out_specs=[tile, tile, cell, cell],
        out_shape=[
            jax.ShapeDtypeStruct((layers, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((layers, p_pad), jnp.float32),
            jax.ShapeDtypeStruct((layers, nb), jnp.float32),
            jax.ShapeDtypeStruct((layers, nb), jnp.float32),
        ],
        interpret=interpret,
    )(c, xf, gf, mf, vf)

    w_norm = jnp.sqrt(jnp.sum(xsq, axis=1))
    u_norm = jnp.sqrt(jnp.sum(usq, axis=1))
    if phi_lo is not None or phi_hi is not None:
        w_norm = jnp.clip(
            w_norm,
            phi_lo if phi_lo is not None else 0.0,
            phi_hi if phi_hi is not None else jnp.inf,
        )
    ratio = jnp.where(w_norm > 0, jnp.where(u_norm > 0, w_norm / u_norm, 1.0), 1.0)
    if not apply_trust:
        ratio = jnp.ones_like(ratio)
    trust = ratio  # pre-lr applied ratio (telemetry aux)
    if lr_t is not None:
        ratio = ratio * lr_t.astype(jnp.float32)
    ratio = ratio.reshape(layers, 1)

    rcell = pl.BlockSpec((1, 1), lambda l, i: (l, 0))
    x_new = pl.pallas_call(
        functools.partial(_apply_kernel, eps=eps, wd=weight_decay, lr=lr),
        grid=(layers, nb),
        in_specs=[scal, rcell, tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((layers, p_pad), orig_dtype),
        interpret=interpret,
    )(c, ratio, xf, m_new, v_new)

    def unflat(a, dtype):
        return a[:, :per_layer].reshape(orig_shape).astype(dtype)

    out = (
        unflat(x_new, orig_dtype),
        unflat(m_new, jnp.float32),
        unflat(v_new, jnp.float32),
    )
    if return_ratio:
        out += (trust if stacked else jnp.squeeze(trust),)
    return out
