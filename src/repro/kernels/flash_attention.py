"""Differentiable flash attention — Pallas TPU kernels (fwd + bwd).

Block-wise online-softmax attention: never materializes the (S, T) score
matrix, in the forward *or* the backward pass (the dominant train temp in
the dry-run memory analysis).  Three pieces share one ``jax.custom_vjp``:

  * **forward** — grid ``(batch*heads, q_blocks, kv_blocks)`` with the kv
    axis innermost; running max / denominator / accumulator live in VMEM
    scratch and the output tile is written once at the last kv block.  The
    forward also emits the per-row ``logsumexp`` residual the backward
    needs to recompute softmax probabilities block-locally.
  * **backward dq** — same grid as the forward; recomputes block logits
    from (q, k) + logsumexp, forms ``ds = p * (do·vᵀ - di)`` and
    accumulates ``dq += ds·k`` in fp32 VMEM scratch.
  * **backward dk/dv** — grid ``(batch*kv_heads, kv_blocks, group*q_blocks)``
    with the (q-head-in-group × q-block) axis innermost, so one grid cell
    owns a dk/dv tile and sums every query head of its GQA group into VMEM
    scratch — no materialized K/V repeat and no cross-cell races.

GQA is folded into the kernel index maps: q is ``(B, H, S, D)`` while k/v
stay ``(B, Hkv, T, D)``; the k/v BlockSpecs map each q head to its kv head
(``kv_head = head // (H // Hkv)``) so grouped heads *share* the K/V tiles
in VMEM instead of reading repeated copies from HBM.

Masking: ``causal`` (with the standard ``T - S`` row offset for
cross-length causal attention), sliding ``window``, and a per-example
``kv_valid`` length (keys at positions ``>= kv_valid[b]`` are masked for
every query row — this is the padding path that lets wrappers pad ragged
sequence lengths up to the 128-aligned block size).  Fully-masked kv
blocks are skipped via ``pl.when`` on block indices.

Backends: ``pallas`` (TPU), ``interpret`` (Pallas interpreter — tests),
and ``xla`` — a chunked ``lax.scan`` implementation of the *same* math
(same custom-VJP boundary, same residuals) that serves as the portable
CPU/GPU fallback, mirroring the ``fused_lamb`` backend scheme.

Block sizes default to (128, 128) q×kv tiles — MXU-aligned (128 lanes) and
small enough that q, k, v, acc tiles fit VMEM comfortably
(4 · 128 · head_dim · 4B ≈ 256 KiB at head_dim=128).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class FlashSpec(NamedTuple):
    """Static (hashable) kernel configuration — the custom_vjp nondiff arg."""

    scale: float
    causal: bool
    window: int          # sliding-window size; 0 = full attention
    block_q: int
    block_k: int
    use_valid: bool      # apply the per-example kv_valid length mask
    backend: str         # "pallas" | "interpret" | "xla"


# ---------------------------------------------------------------------------
# shared mask algebra (kernels and XLA fallback use the same formulas)
# ---------------------------------------------------------------------------

def _mask_conds(spec: FlashSpec, rows, cols, offset: int, valid):
    """Boolean keep-mask over a (rows, cols) logits tile.

    ``rows``/``cols`` are absolute q/kv indices; ``offset = T - S`` aligns
    causal masking for cross-length attention (matches ``flash_attention_ref``).
    Returns None when nothing is masked (lets callers skip the select).
    """
    ok = None

    def _and(a, b):
        return b if a is None else jnp.logical_and(a, b)

    if spec.causal:
        ok = _and(ok, cols <= rows + offset)
    if spec.window:
        ok = _and(ok, cols > rows + offset - spec.window)
    if spec.use_valid:
        ok = _and(ok, cols < valid)
    return ok


def _block_run(spec: FlashSpec, qi, ki, offset: int, valid):
    """Whether a (q-block qi, kv-block ki) tile has any unmasked entry."""
    bq, bk = spec.block_q, spec.block_k
    run = None

    def _and(a, b):
        return b if a is None else jnp.logical_and(a, b)

    if spec.causal:
        # lowest kv col of the block must be <= highest causal col of the block
        run = _and(run, ki * bk <= (qi + 1) * bq - 1 + offset)
    if spec.window:
        # highest kv col must be inside the window of the highest q row
        run = _and(run, (ki + 1) * bk - 1 > qi * bq + offset - spec.window)
    if spec.use_valid:
        run = _and(run, ki * bk < valid)
    return run


def _maybe_when(run, body):
    if run is None:
        body()
    else:
        pl.when(run)(body)


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(
    q_ref, k_ref, v_ref, valid_ref, o_ref, lse_ref,
    acc_ref, m_ref, l_ref,
    *, spec: FlashSpec, offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    bq, bk = spec.block_q, spec.block_k
    valid = valid_ref[0, 0] if spec.use_valid else None

    @pl.when(ki == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * spec.scale                             # (bq, bk)
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = _mask_conds(spec, rows, cols, offset, valid)
        if ok is not None:
            s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # (bq, bk)
        if ok is not None:
            # fully-masked rows (window ∩ valid can be empty for pad rows)
            # would otherwise see exp(NEG_INF - NEG_INF) = 1: force p = 0 so
            # such rows yield o = 0 and zero gradients instead of garbage
            p = jnp.where(ok, p, 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    # skip kv blocks entirely above the diagonal (causal), entirely left of
    # the sliding window, or entirely past the valid kv length — THIS is
    # where the FLOP savings come from (a dense masked softmax saves none)
    _maybe_when(_block_run(spec, qi, ki, offset, valid), body)

    @pl.when(ki == nk - 1)
    def finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------

def _recompute_p_ds(spec, offset, valid, qi, ki, q, k, v, do, lse, di):
    """Block-local recompute shared by both backward kernels.

    Returns (p, ds) for one (bq, bk) tile: ``p = softmax(qkᵀ)`` rebuilt from
    the logsumexp residual, ``ds = p * (do·vᵀ - di)``.
    """
    bq, bk = spec.block_q, spec.block_k
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * spec.scale                                 # (bq, bk)
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = _mask_conds(spec, rows, cols, offset, valid)
    if ok is not None:
        s = jnp.where(ok, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                  # (bq, bk), rows sum to 1
    if ok is not None:
        # fully-masked rows have lse ≈ NEG_INF, where exp(s - lse) != 0:
        # zero them so dk/dv/dq see exactly the forward's p = 0
        p = jnp.where(ok, p, 0.0)
    dp = jax.lax.dot_general(                      # do · vᵀ
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    ds = p * (dp - di[:, None])
    return p, ds


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref, valid_ref, dq_ref,
    acc_ref,
    *, spec: FlashSpec, offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    valid = valid_ref[0, 0] if spec.use_valid else None

    @pl.when(ki == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        _, ds = _recompute_p_ds(
            spec, offset, valid, qi, ki, q, k, v, do, lse_ref[0], di_ref[0]
        )
        acc_ref[...] += spec.scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    _maybe_when(_block_run(spec, qi, ki, offset, valid), body)

    @pl.when(ki == nk - 1)
    def finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, di_ref, valid_ref,
    dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, spec: FlashSpec, offset: int, nq: int,
):
    ki = pl.program_id(1)
    ti = pl.program_id(2)      # enumerates (head-in-group, q-block) pairs
    nt = pl.num_programs(2)
    qi = ti % nq
    valid = valid_ref[0, 0] if spec.use_valid else None

    @pl.when(ti == 0)
    def init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, ds = _recompute_p_ds(
            spec, offset, valid, qi, ki, q, k, v, do, lse_ref[0], di_ref[0]
        )
        dv_acc[...] += jax.lax.dot_general(        # pᵀ · do
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )
        dk_acc[...] += spec.scale * jax.lax.dot_general(  # dsᵀ · q
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    _maybe_when(_block_run(spec, qi, ki, offset, valid), body)

    @pl.when(ti == nt - 1)
    def finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call plumbing
# ---------------------------------------------------------------------------

def _kv_imap(h: int, hkv: int):
    """Map a flat q-head grid index to its (shared) kv-head block — the GQA
    fold: grouped q heads read the same K/V tile instead of a repeated copy."""
    group = h // hkv
    return lambda g, i, j: ((g // h) * hkv + (g % h) // group, j, 0)


def _valid_spec(h_per_b: int):
    imap = lambda g, i, j: (g // h_per_b, 0)
    return pl.BlockSpec((1, 1), imap, memory_space=pltpu.SMEM)


def _pallas_fwd(spec: FlashSpec, q, k, v, valid):
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    bq, bk = spec.block_q, spec.block_k
    interpret = spec.backend == "interpret"
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * hkv, t, d)
    vf = v.reshape(b * hkv, t, d)
    valid2 = valid.reshape(b, 1)

    grid = (b * h, s // bq, t // bk)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec, offset=t - s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), _kv_imap(h, hkv)),
            pl.BlockSpec((1, bk, d), _kv_imap(h, hkv)),
            _valid_spec(h),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b * h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # denominator l
        ],
        interpret=interpret,
    )(qf, kf, vf, valid2)
    return o.reshape(b, h, s, d), lse.reshape(b, h, s)


def _pallas_bwd(spec: FlashSpec, q, k, v, valid, o, lse, do):
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    group = h // hkv
    bq, bk = spec.block_q, spec.block_k
    nq, nk = s // bq, t // bk
    interpret = spec.backend == "interpret"
    offset = t - s

    # di = rowwise(o · do) — needed by both kernels; cheap fp32 jnp reduction
    di = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * hkv, t, d)
    vf = v.reshape(b * hkv, t, d)
    dof = do.reshape(b * h, s, d)
    lsef = lse.reshape(b * h, s)
    dif = di.reshape(b * h, s)
    valid2 = valid.reshape(b, 1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, spec=spec, offset=offset),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), _kv_imap(h, hkv)),
            pl.BlockSpec((1, bk, d), _kv_imap(h, hkv)),
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
            pl.BlockSpec((1, bq), lambda g, i, j: (g, i)),
            _valid_spec(h),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, dif, valid2)

    # dk/dv: one grid cell per kv tile; the innermost axis walks every
    # (q head of the GQA group × q block), summing into VMEM scratch
    def q_imap(n, jk, ti):
        return ((n // hkv) * h + (n % hkv) * group + ti // nq, ti % nq, 0)

    def qrow_imap(n, jk, ti):
        return ((n // hkv) * h + (n % hkv) * group + ti // nq, ti % nq)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, spec=spec, offset=offset, nq=nq),
        grid=(b * hkv, nk, group * nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_imap),
            pl.BlockSpec((1, bk, d), lambda n, jk, ti: (n, jk, 0)),
            pl.BlockSpec((1, bk, d), lambda n, jk, ti: (n, jk, 0)),
            pl.BlockSpec((1, bq, d), q_imap),
            pl.BlockSpec((1, bq), qrow_imap),
            pl.BlockSpec((1, bq), qrow_imap),
            _valid_spec(hkv),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda n, jk, ti: (n, jk, 0)),
            pl.BlockSpec((1, bk, d), lambda n, jk, ti: (n, jk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, t, d), k.dtype),
            jax.ShapeDtypeStruct((b * hkv, t, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),   # dk accumulator
            pltpu.VMEM((bk, d), jnp.float32),   # dv accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lsef, dif, valid2)

    return (
        dq.reshape(b, h, s, d),
        dk.reshape(b, hkv, t, d),
        dv.reshape(b, hkv, t, d),
    )


# ---------------------------------------------------------------------------
# XLA fallback: the same chunked online-softmax math as a lax.scan —
# portable to CPU/GPU, and the backward below recomputes block logits from
# the logsumexp residual exactly like the Pallas kernels (same VJP boundary,
# so memory stays O(S·block) instead of O(S·T) on every backend).
# ---------------------------------------------------------------------------

def _xla_chunks(spec: FlashSpec, k):
    """Pad kv to a block multiple and reshape to scan chunks (nk leading)."""
    b, hkv, t, d = k.shape
    bk = spec.block_k
    pad = -t % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (t + pad) // bk
    return k.reshape(b, hkv, nk, bk, d).transpose(2, 0, 1, 3, 4), nk


def _xla_mask(spec: FlashSpec, j, s, t, valid, offset):
    """(B, 1, 1, S, bk) keep-mask for kv chunk j (None if nothing masked)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, spec.block_k), 0)
    cols = j * spec.block_k + jax.lax.broadcasted_iota(
        jnp.int32, (s, spec.block_k), 1
    )
    geo = _mask_conds(spec._replace(use_valid=False), rows, cols, offset, None)
    has_pad = bool(-t % spec.block_k)  # kv pad from _xla_chunks: always masked
    if not spec.use_valid and not has_pad:
        return None if geo is None else geo[None, None, None]
    lim = jnp.minimum(valid, t) if spec.use_valid else jnp.full_like(valid, t)
    ok = cols[None] < lim[:, None, None]            # (B, S, bk)
    if geo is not None:
        ok = jnp.logical_and(ok, geo[None])
    return ok[:, None, None]


def _xla_fwd(spec: FlashSpec, q, k, v, valid):
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    offset = t - s
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    kc, nk = _xla_chunks(spec, k)
    vc, _ = _xla_chunks(spec, v)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        sij = jnp.einsum(
            "bngsd,bntd->bngst", qg, kj.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * spec.scale
        ok = _xla_mask(spec, j, s, t, valid, offset)
        if ok is not None:
            sij = jnp.where(ok, sij, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sij, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sij - m_new[..., None])
        if ok is not None:
            p = jnp.where(ok, p, 0.0)   # fully-masked rows: p = 0, not 1
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bngst,bntd->bngsd", p, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    init = (
        jnp.full((b, hkv, g, s), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, s), jnp.float32),
        jnp.zeros((b, hkv, g, s, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, jnp.arange(nk)))
    l = jnp.maximum(l, 1e-30)
    o = (acc / l[..., None]).reshape(b, h, s, d).astype(q.dtype)
    lse = (m + jnp.log(l)).reshape(b, h, s)
    return o, lse


def _xla_bwd(spec: FlashSpec, q, k, v, valid, o, lse, do):
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    bk = spec.block_k
    offset = t - s
    di = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    dog = do.reshape(b, hkv, g, s, d).astype(jnp.float32)
    lseg = lse.reshape(b, hkv, g, s)
    dig = di.reshape(b, hkv, g, s)
    kc, nk = _xla_chunks(spec, k)
    vc, _ = _xla_chunks(spec, v)

    def body(dq, xs):
        kj, vj, j = xs
        sij = jnp.einsum(
            "bngsd,bntd->bngst", qg, kj.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * spec.scale
        ok = _xla_mask(spec, j, s, t, valid, offset)
        if ok is not None:
            sij = jnp.where(ok, sij, NEG_INF)
        p = jnp.exp(sij - lseg[..., None])          # (b,n,g,s,bk)
        if ok is not None:
            # fully-masked rows have lse ≈ NEG_INF: zero p as in the forward
            p = jnp.where(ok, p, 0.0)
        dp = jnp.einsum(
            "bngsd,bntd->bngst", dog, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dp - dig[..., None])
        dkj = spec.scale * jnp.einsum("bngst,bngsd->bntd", ds, qg)
        dvj = jnp.einsum("bngst,bngsd->bntd", p, dog)
        dq = dq + spec.scale * jnp.einsum(
            "bngst,bntd->bngsd", ds, kj.astype(jnp.float32)
        )
        return dq, (dkj, dvj)

    dq0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    dq, (dkc, dvc) = jax.lax.scan(body, dq0, (kc, vc, jnp.arange(nk)))
    dk = dkc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nk * bk, d)[:, :, :t]
    dv = dvc.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nk * bk, d)[:, :, :t]
    return (
        dq.reshape(b, h, s, d).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


# ---------------------------------------------------------------------------
# custom VJP: one boundary, three backends
# ---------------------------------------------------------------------------

def _fwd_impl(spec: FlashSpec, q, k, v, valid):
    if spec.backend == "xla":
        return _xla_fwd(spec, q, k, v, valid)
    return _pallas_fwd(spec, q, k, v, valid)


def _bwd_impl(spec: FlashSpec, q, k, v, valid, o, lse, do):
    if spec.backend == "xla":
        return _xla_bwd(spec, q, k, v, valid, o, lse, do)
    return _pallas_bwd(spec, q, k, v, valid, o, lse, do)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(spec: FlashSpec, q, k, v, valid):
    o, _ = _fwd_impl(spec, q, k, v, valid)
    return o


def _flash_fwd(spec: FlashSpec, q, k, v, valid):
    o, lse = _fwd_impl(spec, q, k, v, valid)
    return o, (q, k, v, valid, o, lse)


def _flash_bwd(spec: FlashSpec, res, do):
    q, k, v, valid, o, lse = res
    dq, dk, dv = _bwd_impl(spec, q, k, v, valid, o, lse, do)
    # valid lengths are integers: symbolically-zero cotangent
    return dq, dk, dv, np.zeros(valid.shape, jax.dtypes.float0)


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret",
                     "window", "backend"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, Hkv, T, D) — Hkv must divide H (GQA)
    v: jnp.ndarray,  # (B, Hkv, T, D)
    kv_valid: Optional[jnp.ndarray] = None,  # (B,) int32 valid kv lengths
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    window: int = 0,   # sliding-window size; 0 = full attention
    backend: str = "pallas",  # pallas | interpret | xla
) -> jnp.ndarray:
    """Differentiable flash attention; ``jax.grad`` works through it.

    Sequence lengths must divide the (possibly clamped) block sizes —
    ``flash_sdpa`` pads ragged lengths and masks the pad via ``kv_valid``.
    Keys at positions ``>= kv_valid[b]`` are masked out for every query row
    of example ``b`` (bidirectional padding / ragged-batch support).
    """
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    if h % max(hkv, 1):
        raise ValueError(f"n_heads {h} not a multiple of kv heads {hkv}")
    scale = scale if scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    if interpret and backend == "pallas":
        backend = "interpret"
    if backend not in ("pallas", "interpret", "xla"):
        raise ValueError(f"unknown flash backend {backend!r}")
    if backend != "xla" and (s % block_q or t % block_k):
        # the xla scan pads/masks its own kv chunks and has no q tiling
        raise ValueError(
            f"seq lens ({s},{t}) must divide blocks ({block_q},{block_k})"
        )

    use_valid = kv_valid is not None
    valid = (
        jnp.clip(kv_valid.astype(jnp.int32), 1, t)
        if use_valid
        else jnp.full((b,), t, jnp.int32)
    )
    spec = FlashSpec(
        scale=float(scale), causal=causal, window=window,
        block_q=block_q, block_k=block_k, use_valid=use_valid,
        backend=backend,
    )
    return _flash(spec, q, k, v, valid)
