"""Flash attention (forward) — Pallas TPU kernel.

Block-wise online-softmax attention: never materializes the (S, T) score
matrix (the dominant train/prefill temp in the dry-run memory analysis).
Grid is (batch*heads, q_blocks, kv_blocks) with the kv axis innermost; the
running max / denominator / accumulator live in VMEM scratch and the output
tile is written once at the last kv block.  Causal masking skips fully-masked
kv blocks via ``pl.when`` on block indices.

Block sizes default to (128, 128) q×kv tiles — MXU-aligned (128 lanes) and
small enough that q, k, v, acc tiles fit VMEM comfortably
(4 · 128 · head_dim · 4B ≈ 256 KiB at head_dim=128).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, block_q: int, block_k: int, kv_len: int,
    window: int = 0,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip kv blocks entirely above the diagonal (causal) or entirely left
    # of the sliding window — THIS is where SWA's FLOP savings come from
    # (a dense masked softmax computes the full S×T scores regardless)
    run = True
    if causal:
        run = ki * block_k <= (qi + 1) * block_q - 1
    if window:
        run = jnp.logical_and(
            run, (ki + 1) * block_k - 1 > qi * block_q - window
        )

    @pl.when(run)
    def body():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                  # (bq, bk)
        if causal or window:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            ok = rows >= cols if causal else rows == rows
            if window:
                ok = jnp.logical_and(ok, cols > rows - window)
            s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                     # (bq, bk)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret",
                     "window"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, H, S, D)
    k: jnp.ndarray,  # (B, H, T, D)
    v: jnp.ndarray,  # (B, H, T, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    window: int = 0,   # sliding-window size; 0 = full attention
) -> jnp.ndarray:
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = scale if scale is not None else 1.0 / (d**0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    if s % block_q or t % block_k:
        raise ValueError(f"seq lens ({s},{t}) must divide blocks ({block_q},{block_k})")

    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, t, d)
    vf = v.reshape(bh, t, d)

    grid = (bh, s // block_q, t // block_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=t, window=window,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # denominator l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
