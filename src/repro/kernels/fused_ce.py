"""Fused chunked-vocab cross-entropy — the MLM head without the logits.

The LM head is the dominant activation-memory term of the BERT train step:
a dense head projects every position to the vocab and takes an fp32
``log_softmax`` over a ``(B, S, V)`` tensor, even though MLM supervises
only ~15% of positions.  This module is the second half of the fused-head
path (the first — gathering supervised positions *before* the projection —
lives in ``train/loss.py``): given already-gathered rows ``h`` of shape
``(N, D)`` and the vocab projection ``w`` of shape ``(V, D)``, it streams
vocab chunks through projection + online log-sum-exp so the ``(N, V)``
logits tensor never exists, forward *or* backward.

Three pieces share one ``jax.custom_vjp`` (the PR-3 flash-attention
pattern):

  * **forward** — grid ``(row_blocks, vocab_chunks)`` with the vocab axis
    innermost; running max / denominator / label-logit / argmax statistics
    live in fp32 VMEM scratch and the per-row ``(nll, correct, lse)``
    outputs are written once at the last chunk.  ``lse`` is the only
    residual the backward needs.
  * **backward d_hidden** — same grid; recomputes the chunk's softmax
    probabilities from ``p = exp(h·w_cᵀ - lse)``, forms
    ``dlogits = (p - onehot(label)) · g`` and accumulates
    ``dh += dlogits · w_c`` in VMEM scratch.
  * **backward d_w** — grid ``(vocab_chunks, row_blocks)`` with the row
    axis innermost: one grid cell owns a ``(block_v, D)`` weight-gradient
    tile and sums every row block into it (``dw_c += dlogitsᵀ · h``) — the
    per-chunk ``(d_hidden, d_W_vocab)`` emission the fused head needs.

All statistics and accumulators are fp32 regardless of the input dtype
(bf16 rows/weights are upcast per tile), mirroring the mixed-precision
policy of the dense loss (``log_softmax`` in fp32).

Backends: ``pallas`` (TPU), ``interpret`` (Pallas interpreter — tests),
and ``xla`` — a chunked ``lax.scan`` of the *same* math (same custom-VJP
boundary, same ``lse`` residual) that is the portable CPU/GPU default,
resolved by :func:`resolve_ce_backend` exactly like
``resolve_flash_backend`` / ``resolve_fused_backend``.  Because the
reductions in the XLA backend are plain jnp, GSPMD keeps the vocab-chunk
log-sum-exp and both weight-gradient reductions *global* when ``w`` or
``h`` are sharded over a mesh (the PR-4 ``pallas_spec_ok`` concern does
not arise: on non-TPU meshes the resolver never picks the kernel path).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_IDX_INF = np.iinfo(np.int32).max


class CESpec(NamedTuple):
    """Static (hashable) kernel configuration — the custom_vjp nondiff arg."""

    block_n: int   # rows per tile
    block_v: int   # vocab columns per chunk
    vocab: int     # true vocab size; columns >= vocab are padding
    backend: str   # "pallas" | "interpret" | "xla"


def resolve_ce_backend(backend: str = "auto") -> str:
    """Map ``auto`` to the fastest correct CE backend for this platform.

    Mirrors :func:`repro.kernels.ops.resolve_flash_backend`: the Pallas
    kernels only come back on TPU; elsewhere the chunked-``lax.scan`` XLA
    implementation (same custom-VJP math, portable) is the default, and
    ``interpret`` runs the Pallas kernels under the interpreter (tests).
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla", "interpret"):
        raise ValueError(f"unknown fused-CE backend {backend!r}")
    return backend


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(
    h_ref, w_ref, lbl_ref, nll_ref, corr_ref, lse_ref,
    m_ref, l_ref, ll_ref, bmax_ref, bidx_ref,
    *, spec: CESpec,
):
    j = pl.program_id(1)
    nv = pl.num_programs(1)
    bn, bv = spec.block_n, spec.block_v

    @pl.when(j == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        ll_ref[...] = jnp.full_like(ll_ref, NEG_INF)
        bmax_ref[...] = jnp.full_like(bmax_ref, NEG_INF)
        bidx_ref[...] = jnp.zeros_like(bidx_ref)

    h = h_ref[...].astype(jnp.float32)            # (bn, d)
    w = w_ref[...].astype(jnp.float32)            # (bv, d)
    s = jax.lax.dot_general(                      # (bn, bv) chunk logits
        h, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    cols = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    # every chunk in the grid has >= 1 real column (vocab padding < block_v),
    # so the running max below stays finite
    s = jnp.where(cols < spec.vocab, s, NEG_INF)

    m_prev = m_ref[...]                           # (bn, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                        # padded cols underflow to 0
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new

    lbl = lbl_ref[...]                            # (bn,) int32 in [0, vocab)
    hit = cols == lbl[:, None]
    ll_ref[...] = jnp.where(                      # label logit: set exactly once
        jnp.any(hit, axis=1, keepdims=True),
        jnp.sum(jnp.where(hit, s, 0.0), axis=1, keepdims=True),
        ll_ref[...],
    )
    # running argmax with first-occurrence tie-breaking (jnp.argmax semantics):
    # within the chunk take the lowest column achieving the max; across chunks
    # a strict > keeps the earlier chunk's winner
    cand = jnp.min(jnp.where(s == m_cur, cols, _IDX_INF), axis=1, keepdims=True)
    better = m_cur > bmax_ref[...]
    bidx_ref[...] = jnp.where(better, cand, bidx_ref[...])
    bmax_ref[...] = jnp.maximum(bmax_ref[...], m_cur)

    @pl.when(j == nv - 1)
    def finish():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        lse_ref[...] = lse[:, 0]
        nll_ref[...] = (lse - ll_ref[...])[:, 0]
        corr_ref[...] = (bidx_ref[...][:, 0] == lbl).astype(jnp.float32)


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------

def _chunk_dlogits(spec: CESpec, j, h, w, lbl, g, lse):
    """(p - onehot(label)) · g for one (bn, bv) tile, rebuilt from ``lse``."""
    bn, bv = spec.block_n, spec.block_v
    s = jax.lax.dot_general(
        h, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    cols = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    s = jnp.where(cols < spec.vocab, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                 # padded cols -> 0
    onehot = (cols == lbl[:, None]).astype(jnp.float32)
    return (p - onehot) * g[:, None]


def _dh_kernel(
    h_ref, w_ref, lbl_ref, g_ref, lse_ref, dh_ref, acc_ref, *, spec: CESpec
):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dlog = _chunk_dlogits(spec, j, h, w, lbl_ref[...], g_ref[...], lse_ref[...])
    acc_ref[...] += jax.lax.dot_general(          # (bn, d)
        dlog, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(j == nv - 1)
    def finish():
        dh_ref[...] = acc_ref[...].astype(dh_ref.dtype)


def _dw_kernel(
    h_ref, w_ref, lbl_ref, g_ref, lse_ref, dw_ref, acc_ref, *, spec: CESpec
):
    i = pl.program_id(0)       # vocab chunk (owns the dw tile)
    t = pl.program_id(1)       # row block, innermost
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    dlog = _chunk_dlogits(spec, i, h, w, lbl_ref[...], g_ref[...], lse_ref[...])
    acc_ref[...] += jax.lax.dot_general(          # dlogᵀ · h  -> (bv, d)
        dlog, h, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    @pl.when(t == nt - 1)
    def finish():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call plumbing
# ---------------------------------------------------------------------------

def _pallas_fwd(spec: CESpec, h, w, lbl):
    n, d = h.shape
    vp = w.shape[0]
    bn, bv = spec.block_n, spec.block_v
    interpret = spec.backend == "interpret"
    row = lambda i, j: (i,)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    nll, corr, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec),
        grid=(n // bn, vp // bv),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), row),
        ],
        out_specs=[pl.BlockSpec((bn,), row)] * 3,
        out_shape=[vec, vec, vec],
        scratch_shapes=[
            pltpu.VMEM((bn, 1), jnp.float32),   # running max m
            pltpu.VMEM((bn, 1), jnp.float32),   # denominator l
            pltpu.VMEM((bn, 1), jnp.float32),   # label logit
            pltpu.VMEM((bn, 1), jnp.float32),   # best (argmax) value
            pltpu.VMEM((bn, 1), jnp.int32),     # best (argmax) index
        ],
        interpret=interpret,
    )(h, w, lbl)
    return nll, corr, lse


def _pallas_bwd(spec: CESpec, h, w, lbl, lse, g):
    n, d = h.shape
    vp = w.shape[0]
    bn, bv = spec.block_n, spec.block_v
    interpret = spec.backend == "interpret"

    dh = pl.pallas_call(
        functools.partial(_dh_kernel, spec=spec),
        grid=(n // bn, vp // bv),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), h.dtype),
        scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
        interpret=interpret,
    )(h, w, lbl, g, lse)

    dw = pl.pallas_call(
        functools.partial(_dw_kernel, spec=spec),
        grid=(vp // bv, n // bn),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, t: (t, 0)),
            pl.BlockSpec((bv, d), lambda i, t: (i, 0)),
            pl.BlockSpec((bn,), lambda i, t: (t,)),
            pl.BlockSpec((bn,), lambda i, t: (t,)),
            pl.BlockSpec((bn,), lambda i, t: (t,)),
        ],
        out_specs=pl.BlockSpec((bv, d), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((vp, d), w.dtype),
        scratch_shapes=[pltpu.VMEM((bv, d), jnp.float32)],
        interpret=interpret,
    )(h, w, lbl, g, lse)
    return dh, dw


# ---------------------------------------------------------------------------
# XLA fallback: the same chunked online-LSE math as a lax.scan — portable to
# CPU/GPU, same custom-VJP boundary/residuals, and memory O(N·block_v)
# instead of O(N·V) on every backend.
# ---------------------------------------------------------------------------

def _xla_chunks(spec: CESpec, w):
    nv = w.shape[0] // spec.block_v
    return w.reshape(nv, spec.block_v, w.shape[1]), nv


def _xla_fwd(spec: CESpec, h, w, lbl):
    n = h.shape[0]
    hf = h.astype(jnp.float32)
    wc, nv = _xla_chunks(spec, w)
    bv = spec.block_v

    def body(carry, xs):
        m, l, ll, bmax, bidx = carry
        wj, j = xs
        s = jax.lax.dot_general(
            hf, wj.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                          # (n, bv)
        cols = j * bv + jnp.arange(bv, dtype=jnp.int32)
        s = jnp.where(cols[None, :] < spec.vocab, s, NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = alpha * l + jnp.sum(p, axis=1)
        hit = cols[None, :] == lbl[:, None]
        ll = jnp.where(
            jnp.any(hit, axis=1), jnp.sum(jnp.where(hit, s, 0.0), axis=1), ll
        )
        cand = jnp.min(
            jnp.where(s == m_cur[:, None], cols[None, :], _IDX_INF), axis=1
        )
        better = m_cur > bmax
        bidx = jnp.where(better, cand, bidx)
        bmax = jnp.maximum(bmax, m_cur)
        return (m_new, l, ll, bmax, bidx), None

    init = (
        jnp.full((n,), NEG_INF, jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.full((n,), NEG_INF, jnp.float32),
        jnp.full((n,), NEG_INF, jnp.float32),
        jnp.zeros((n,), jnp.int32),
    )
    (m, l, ll, bmax, bidx), _ = jax.lax.scan(
        body, init, (wc, jnp.arange(nv))
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return lse - ll, (bidx == lbl).astype(jnp.float32), lse


def _xla_bwd(spec: CESpec, h, w, lbl, lse, g):
    hf = h.astype(jnp.float32)
    wc, nv = _xla_chunks(spec, w)
    bv = spec.block_v

    def body(dh, xs):
        wj, j = xs
        wjf = wj.astype(jnp.float32)
        s = jax.lax.dot_general(
            hf, wjf, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        cols = j * bv + jnp.arange(bv, dtype=jnp.int32)
        s = jnp.where(cols[None, :] < spec.vocab, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        onehot = (cols[None, :] == lbl[:, None]).astype(jnp.float32)
        dlog = (p - onehot) * g[:, None]
        dwj = jax.lax.dot_general(                 # (bv, d) per-chunk emission
            dlog, hf, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dh = dh + jax.lax.dot_general(
            dlog, wjf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return dh, dwj

    dh0 = jnp.zeros(hf.shape, jnp.float32)
    dh, dwc = jax.lax.scan(body, dh0, (wc, jnp.arange(nv)))
    dw = dwc.reshape(-1, h.shape[1])
    return dh.astype(h.dtype), dw.astype(w.dtype)


# ---------------------------------------------------------------------------
# custom VJP: one boundary, three backends
# ---------------------------------------------------------------------------

def _fwd_impl(spec: CESpec, h, w, lbl):
    if spec.backend == "xla":
        return _xla_fwd(spec, h, w, lbl)
    return _pallas_fwd(spec, h, w, lbl)

def _bwd_impl(spec: CESpec, h, w, lbl, lse, g):
    if spec.backend == "xla":
        return _xla_bwd(spec, h, w, lbl, lse, g)
    return _pallas_bwd(spec, h, w, lbl, lse, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_ce(spec: CESpec, h, w, lbl):
    nll, correct, _ = _fwd_impl(spec, h, w, lbl)
    return nll, correct


def _fused_ce_fwd(spec: CESpec, h, w, lbl):
    nll, correct, lse = _fwd_impl(spec, h, w, lbl)
    return (nll, correct), (h, w, lbl, lse)


def _fused_ce_bwd(spec: CESpec, res, cts):
    h, w, lbl, lse = res
    d_nll, _d_correct = cts   # ``correct`` is piecewise constant: grad 0 a.e.
    dh, dw = _bwd_impl(spec, h, w, lbl, lse, d_nll.astype(jnp.float32))
    # labels are integers: symbolically-zero cotangent
    return dh, dw, np.zeros(lbl.shape, jax.dtypes.float0)


_fused_ce.defvjp(_fused_ce_fwd, _fused_ce_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("backend", "block_n", "block_v", "interpret")
)
def fused_ce(
    h: jnp.ndarray,        # (N, D) gathered rows (any float dtype)
    w: jnp.ndarray,        # (V, D) vocab projection, embedding layout
    labels: jnp.ndarray,   # (N,) int targets; clipped into [0, V)
    *,
    backend: str = "auto",     # auto | pallas | interpret | xla
    block_n: int = 128,
    block_v: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row ``(nll, correct)`` without materializing the (N, V) logits.

    ``nll[i] = logsumexp_v(h[i]·w[v]) - h[i]·w[labels[i]]`` in fp32;
    ``correct[i] = argmax_v(h[i]·w[v]) == labels[i]`` with ``jnp.argmax``
    first-occurrence tie semantics.  Differentiable w.r.t. ``h`` and ``w``
    through ``jax.custom_vjp`` (``correct`` contributes zero gradient).

    Rows the caller wants ignored should simply receive zero cotangent
    (multiply their ``nll`` by a 0 weight in the loss) — their ``dh``/``dw``
    contributions then vanish exactly.  The weight is expected in the
    ``(V, D)`` embedding layout; transpose a ``(D, V)`` unembed matrix
    before calling.
    """
    n, d = h.shape
    v, dw_ = w.shape
    if dw_ != d:
        raise ValueError(f"h feature dim {d} != w feature dim {dw_}")
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    if interpret:
        if backend == "xla":
            raise ValueError("interpret=True conflicts with backend='xla'")
        mode = "interpret"
    else:
        mode = resolve_ce_backend(backend)

    lbl = jnp.clip(labels.astype(jnp.int32), 0, v - 1)
    bv = min(block_v, v)
    pad_v = -v % bv
    if pad_v:  # padded vocab columns are masked via spec.vocab
        w = jnp.pad(w, ((0, pad_v), (0, 0)))
    bn = min(block_n, n)
    pad_n = -n % bn if mode != "xla" else 0
    if pad_n:  # pad rows are sliced off below; their cotangents are zero,
        # so dh pad rows vanish and dw never sees them (g = 0)
        h = jnp.pad(h, ((0, pad_n), (0, 0)))
        lbl = jnp.pad(lbl, (0, pad_n))

    spec = CESpec(block_n=bn, block_v=bv, vocab=v, backend=mode)
    nll, correct = _fused_ce(spec, h, w, lbl)
    if pad_n:
        nll, correct = nll[:n], correct[:n]
    return nll, correct
