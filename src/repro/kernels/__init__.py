from repro.kernels.flash_attention import FlashSpec, flash_attention
from repro.kernels.fused_ce import CESpec, fused_ce, resolve_ce_backend
from repro.kernels.lamb_update import lamb_update
from repro.kernels.ops import (
    FusedLambState,
    flash_sdpa,
    fused_lamb,
    fused_lamb_apply,
    fused_lamb_init,
    make_fused_lamb_step,
    pallas_spec_ok,
    resolve_flash_backend,
    resolve_fused_backend,
)

__all__ = [
    "CESpec",
    "FlashSpec",
    "FusedLambState",
    "flash_attention",
    "flash_sdpa",
    "fused_ce",
    "fused_lamb",
    "fused_lamb_apply",
    "fused_lamb_init",
    "lamb_update",
    "make_fused_lamb_step",
    "pallas_spec_ok",
    "resolve_ce_backend",
    "resolve_flash_backend",
    "resolve_fused_backend",
]
