from repro.kernels.flash_attention import flash_attention
from repro.kernels.lamb_update import lamb_update
from repro.kernels.ops import flash_sdpa, fused_lamb

__all__ = ["flash_attention", "flash_sdpa", "fused_lamb", "lamb_update"]
