"""jit'd wrappers around the Pallas kernels + optimizer/model integration.

``fused_lamb`` is a drop-in GradientTransformation equivalent to
``repro.core.lamb`` (tested for exact agreement) but whose per-leaf update is
a *fused* LAMB step — the beyond-paper bandwidth optimization for the
optimizer step (§Perf).  Two backends share one semantics:

  * ``pallas``    — the two-pass Pallas TPU kernel (≈10 N HBM traffic vs
                    ≈21 N for the unfused transform chain);
  * ``xla``       — a single fused jnp expression per leaf
                    (``kernels.ref.lamb_update_ref``) that XLA fuses into few
                    passes — the portable fallback for CPU/GPU where Pallas
                    would run in (slow) interpret mode;
  * ``interpret`` — the Pallas kernel in interpret mode (tests only);
  * ``auto``      — ``pallas`` on TPU, ``xla`` elsewhere.

``flash_sdpa`` adapts the differentiable flash-attention kernel to the
model layout (B, S, H, D) for the train/prefill paths: GQA is folded into
the kernel index maps (no materialized K/V repeat), ragged sequence
lengths are padded to the block multiple and masked via the kernel's
valid-length path, and ``resolve_flash_backend`` picks Pallas on TPU vs
the chunked-XLA scan elsewhere (same backend scheme as ``fused_lamb``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec

from repro.kernels.flash_attention import flash_attention
from repro.kernels.lamb_update import lamb_update
from repro.kernels.ref import lamb_update_ref
from repro.optim.base import (
    GradientTransformation,
    ScalarOrSchedule,
    clip_tree_by_global_norm,
)


def pallas_spec_ok(spec) -> bool:
    """True if a parameter with this PartitionSpec can feed the Pallas kernel.

    The fused kernel flattens each leaf to a padded ``(layers, P)`` view and
    grids over it on one device — valid only for replicated leaves.  A leaf
    sharded on any mesh axis (FSDP ``embed``, TP ``heads``/``ff``) must take
    the fused-XLA ``lamb_update_ref`` path instead, where GSPMD inserts the
    collectives that keep the per-layer ‖x‖/‖u‖ trust-ratio reductions
    *global* across shards.  ``None`` (no spec known) is treated as
    replicated.
    """
    return spec is None or all(e is None for e in spec)


class FusedLambState(NamedTuple):
    """Fused-LAMB optimizer state.

    ``count`` ages the moments (bias correction) and must carry across
    mixed-batch stage switches; ``sched_count`` drives LR schedules and is
    what stage-2 re-warm-up resets (mirrors the split between
    ScaleByAdamState.count and ScheduleState.count in the unfused chain).
    """

    count: jnp.ndarray
    sched_count: jnp.ndarray
    mu: Any
    nu: Any


def fused_lamb_init(params) -> FusedLambState:
    """Zero moments (always fp32 — mixed-precision masters) + zero counters."""
    zeros = lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return FusedLambState(
        jnp.zeros([], jnp.int32), jnp.zeros([], jnp.int32), zeros(), zeros()
    )


def fused_lamb_apply(
    params: Any,
    grads: Any,
    mu: Any,
    nu: Any,
    count: jnp.ndarray,
    lr_t: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    wd_mask: Optional[Any] = None,
    trust_mask: Optional[Any] = None,
    layer_axes: Optional[Any] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    mode: str = "xla",
    param_specs: Optional[Any] = None,
    with_aux: bool = False,
    ok: Optional[jnp.ndarray] = None,
) -> Tuple[Any, ...]:
    """One fused LAMB step over a whole pytree: (params', mu', nu').

    ``ok`` (scalar bool, optional) is the non-finite guard: when False the
    computed update is discarded leaf-by-leaf — params and both moments
    where-select back to their inputs inside the same fused expression, so
    a skipped step costs no extra memory traffic and the state comes back
    bit-identical.  The caller gates the counters (see
    :func:`make_fused_lamb_step`).

    ``with_aux=True`` appends a fourth output: a pytree shaped like
    ``params`` of the *applied* per-layer trust ratios (each backend's
    ``return_ratio`` aux — the telemetry recorder's source of truth, no
    recompute from deltas).

    ``count`` is the 1-based step for bias correction and ``lr_t`` the traced
    learning rate; ``mode`` is a *resolved* backend ("pallas" | "xla" |
    "interpret").  ``param_specs`` (a PartitionSpec tree from
    ``sharding.specs_for``) makes the choice sharding-aware: on the pallas
    backend, leaves whose sharding crosses the kernel's single-device block
    layout fall back per-leaf to the fused-XLA path, whose norm reductions
    GSPMD keeps globally correct (see :func:`pallas_spec_ok`).  This is the
    direct-apply core the jit'd train step calls — no parameter-delta
    round-trip — and also what the ``fused_lamb`` GradientTransformation
    wraps for drop-in composition with the optim API.  Invariant: identical
    math to ``core.lamb`` per layer (parity-tested).
    """
    la = layer_axes
    if la is None:
        la = jax.tree.map(lambda _: -1, grads)
    else:
        la = jax.tree.map(
            lambda a: -1 if a is None else a, la,
            is_leaf=lambda x: x is None or isinstance(x, int),
        )
    wm = wd_mask if wd_mask is not None else jax.tree.map(lambda _: True, grads)
    tm = trust_mask if trust_mask is not None else jax.tree.map(lambda _: True, grads)

    treedef = jax.tree_util.tree_structure(grads)
    p_l, g_l = jax.tree.leaves(params), jax.tree.leaves(grads)
    m_l, v_l = jax.tree.leaves(mu), jax.tree.leaves(nu)
    la_l, wm_l, tm_l = jax.tree.leaves(la), jax.tree.leaves(wm), jax.tree.leaves(tm)
    if param_specs is None:
        sp_l = [None] * len(p_l)
    else:
        sp_l = jax.tree.leaves(
            param_specs,
            is_leaf=lambda s: s is None or isinstance(s, PartitionSpec),
        )

    xs, ms, vs, rs = [], [], [], []
    for p, g, m, v, axis, wd_on, tr_on, spec in zip(
        p_l, g_l, m_l, v_l, la_l, wm_l, tm_l, sp_l
    ):
        axis = 0 if axis == 0 else None
        leaf_mode = mode
        if mode != "xla" and not pallas_spec_ok(spec):
            # sharded leaf: the kernel path (pallas AND its interpret mode)
            # assumes a single-device block layout; fall back to the fused
            # XLA expression where GSPMD keeps norm reductions global
            leaf_mode = "xla"
        if leaf_mode == "xla":
            out = lamb_update_ref(
                p, g, m, v, lr=lr_t, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay if wd_on else 0.0,
                step=count, phi_bounds=phi_bounds,
                layer_axis=axis, apply_trust=bool(tr_on),
                return_ratio=with_aux,
            )
        else:
            out = lamb_update(
                p, g, m, v, count, lr_t,
                lr=1.0, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay if wd_on else 0.0,
                phi_lo=None if phi_bounds is None else phi_bounds[0],
                phi_hi=None if phi_bounds is None else phi_bounds[1],
                layer_axis=axis, apply_trust=bool(tr_on),
                interpret=leaf_mode == "interpret",
                return_ratio=with_aux,
            )
        x_new, m_new, v_new = out[0], out[1], out[2]
        if ok is not None:
            x_new = jnp.where(ok, x_new, p)
            m_new = jnp.where(ok, m_new, m)
            v_new = jnp.where(ok, v_new, v)
        xs.append(x_new)
        ms.append(m_new)
        vs.append(v_new)
        if with_aux:
            rs.append(out[3])

    unflat = jax.tree_util.tree_unflatten
    result = (unflat(treedef, xs), unflat(treedef, ms), unflat(treedef, vs))
    if with_aux:
        result += (unflat(treedef, rs),)
    return result


def resolve_fused_backend(backend: str = "auto") -> str:
    """Map ``auto`` to the fastest correct backend for the current platform.

    Invariant: the returned backend is runnable here — ``pallas`` only comes
    back when the default JAX backend is a TPU.
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla", "interpret"):
        raise ValueError(f"unknown fused backend {backend!r}")
    return backend


def make_fused_lamb_step(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[Any] = None,
    trust_mask: Optional[Any] = None,
    layer_axes: Optional[Any] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    grad_clip_norm: Optional[float] = None,
    mode: str = "xla",
    param_specs: Optional[Any] = None,
    with_aux: bool = False,
):
    """The single stateful fused-LAMB core shared by the transform wrapper
    and the jit'd train step's direct path.

    Returns ``step(params, grads, state) -> (new_params, new_state)``:
    clip → count/sched_count advance → lr(sched_count) → fused apply, in
    that order.  ``param_specs`` propagates the per-leaf sharded-parameter
    fallback (see :func:`fused_lamb_apply`).  With ``with_aux`` the step
    returns ``(new_params, new_state, trust_ratios)`` — the applied
    per-layer ratios threaded out for the telemetry recorder.  ``ok``
    (scalar bool) is the train step's non-finite guard: when False the
    apply where-selects everything back to its inputs and *neither counter
    advances* — the skipped step leaves the schedule position untouched.
    Invariant: keeping this sequence in one place is what guarantees
    fused-direct vs transform parity.
    """

    def step(params, grads, state: FusedLambState, ok=None):
        if grad_clip_norm is not None:
            grads = clip_tree_by_global_norm(grads, grad_clip_norm)
        adv = 1 if ok is None else ok.astype(state.count.dtype)
        count = state.count + adv
        lr_t = (
            learning_rate(state.sched_count)
            if callable(learning_rate)
            else jnp.asarray(learning_rate)
        )
        out = fused_lamb_apply(
            params, grads, state.mu, state.nu, count, lr_t,
            b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
            wd_mask=wd_mask, trust_mask=trust_mask, layer_axes=layer_axes,
            phi_bounds=phi_bounds, mode=mode, param_specs=param_specs,
            with_aux=with_aux, ok=ok,
        )
        new_params, new_mu, new_nu = out[:3]
        new_state = FusedLambState(count, state.sched_count + adv,
                                   new_mu, new_nu)
        if with_aux:
            return new_params, new_state, out[3]
        return new_params, new_state

    return step


def fused_lamb(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[Any] = None,
    trust_mask: Optional[Any] = None,
    layer_axes: Optional[Any] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    grad_clip_norm: Optional[float] = None,
    backend: str = "auto",
    interpret: bool = False,
    param_specs: Optional[Any] = None,
) -> GradientTransformation:
    """LAMB with a fused per-leaf update (Pallas kernel or XLA fallback).

    Args mirror :func:`repro.core.lamb` (masks/axes are the model's pytree
    metadata); ``backend`` picks the fused implementation (see module doc),
    and ``interpret=True`` is a legacy alias for ``backend="interpret"``.

    Returns a ``GradientTransformation`` whose ``update`` yields parameter
    *deltas*, so it composes with ``optim.apply_updates`` and ``optim.chain``
    exactly like the unfused chain.  (The jit'd train step bypasses the delta
    round-trip via :func:`make_fused_lamb_step`.)  Invariant: per-layer trust
    ratios match ``core.lamb`` on stacked and unstacked leaves to float
    tolerance (see tests/test_kernels.py).
    """
    mode = "interpret" if interpret else resolve_fused_backend(backend)
    step = make_fused_lamb_step(
        learning_rate, b1, b2, eps, weight_decay,
        wd_mask=wd_mask, trust_mask=trust_mask, layer_axes=layer_axes,
        phi_bounds=phi_bounds, grad_clip_norm=grad_clip_norm, mode=mode,
        param_specs=param_specs,
    )

    def update(grads, state, params=None):
        if params is None:
            raise ValueError("fused_lamb requires params")
        new_params, new_state = step(params, grads, state)
        # Return *updates* (delta) so apply_updates composes like other opts.
        updates = jax.tree.map(
            lambda new, old: (new.astype(jnp.float32) - old.astype(jnp.float32)).astype(old.dtype),
            new_params, params,
        )
        return updates, new_state

    return GradientTransformation(fused_lamb_init, update)


def resolve_flash_backend(backend: str = "auto") -> str:
    """Map ``auto`` to the fastest correct flash backend for this platform.

    Mirrors :func:`resolve_fused_backend`: the Pallas kernels only come back
    on TPU; elsewhere the chunked-``lax.scan`` XLA implementation (same
    custom-VJP math, portable) is the default, and ``interpret`` runs the
    Pallas kernels under the interpreter (tests only — slow).
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla", "interpret"):
        raise ValueError(f"unknown flash backend {backend!r}")
    return backend


def _flash_block(n: int, block: int) -> Tuple[int, int]:
    """(block_size, pad) so that ``n + pad`` divides ``block_size``.

    Lengths already block-divisible (or short sublane-aligned lengths) pass
    through unpadded; ragged lengths are padded up to the 128-lane block —
    this is what lifts the old ``s % 128 == 0`` gate on the kernel path.
    """
    b = block if n >= block else n
    if n % b or b % 8:  # ragged or sublane-misaligned: pad to the full block
        b = block
    return b, -n % b


def flash_sdpa(
    q: jnp.ndarray,  # (B, S, H, D)  model layout
    k: jnp.ndarray,  # (B, T, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    kv_valid: Optional[jnp.ndarray] = None,  # (B,) valid kv lengths
    window: int = 0,  # sliding-window size; 0 = full attention
    interpret: bool = False,
    backend: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """Flash attention on the model's (B, S, H, D) layout, GQA folded into
    the kernel index maps (no materialized K/V repeat), differentiable.

    Ragged sequence lengths are padded to the block multiple here — pad kv
    rows are masked via the kernel's valid-length path and pad q rows are
    sliced off (their cotangents are zero, so gradients stay exact).
    """
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    if interpret:
        # interpret runs the Pallas kernels under the interpreter; an
        # explicit xla request alongside it is a contradiction, not a
        # silent override
        if backend == "xla":
            raise ValueError("interpret=True conflicts with backend='xla'")
        mode = "interpret"
    else:
        mode = resolve_flash_backend(backend)

    qt = q.transpose(0, 2, 1, 3)          # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)          # (B, Hkv, T, D)
    vt = v.transpose(0, 2, 1, 3)

    pad_q = 0
    if mode in ("pallas", "interpret"):
        block_q, pad_q = _flash_block(s, block_q)
        block_k, pad_k = _flash_block(t, block_k)
        if (causal or window) and pad_q != pad_k:
            # asymmetric padding would shift the kernel's causal/window row
            # offset (t - s); self-attention (s == t) pads symmetrically
            raise ValueError(
                f"causal/window cross-length ({s},{t}) needs "
                "block-divisible lengths"
            )
        if pad_q:
            qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        if pad_k:
            kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
            if kv_valid is None:
                kv_valid = jnp.full((b,), t, jnp.int32)
    # (the xla backend masks its own kv-chunk pad; no pre-padding needed)

    o = flash_attention(
        qt, kt, vt, kv_valid, causal=causal, window=window, backend=mode,
        block_q=block_q, block_k=block_k,
    )
    if pad_q:
        o = o[:, :, :s]
    return o.transpose(0, 2, 1, 3)
