"""jit'd wrappers around the Pallas kernels + optimizer/model integration.

``fused_lamb`` is a drop-in GradientTransformation equivalent to
``repro.core.lamb`` (tested for exact agreement) but whose per-leaf update is
the fused two-pass Pallas kernel — the beyond-paper bandwidth optimization
for the optimizer step (§Perf).

``flash_sdpa`` adapts the flash-attention kernel to the model layout
(B, S, H, D) with GQA head expansion, for TPU prefill/train paths.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.lamb_update import lamb_update
from repro.optim.base import GradientTransformation, ScalarOrSchedule


class FusedLambState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def fused_lamb(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[Any] = None,
    trust_mask: Optional[Any] = None,
    layer_axes: Optional[Any] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    interpret: bool = False,
) -> GradientTransformation:
    """LAMB with the fused Pallas update kernel (per parameter leaf)."""

    def init(params):
        zeros = lambda: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params
        )
        return FusedLambState(jnp.zeros([], jnp.int32), zeros(), zeros())

    def update(grads, state, params=None):
        if params is None:
            raise ValueError("fused_lamb requires params")
        count = state.count + 1
        lr_t = (
            learning_rate(state.count)
            if callable(learning_rate)
            else jnp.asarray(learning_rate)
        )

        la = layer_axes
        if la is None:
            la = jax.tree.map(lambda _: -1, grads)
        else:
            la = jax.tree.map(
                lambda a: -1 if a is None else a, la,
                is_leaf=lambda x: x is None or isinstance(x, int),
            )
        wm = wd_mask if wd_mask is not None else jax.tree.map(lambda _: True, grads)
        tm = (
            trust_mask
            if trust_mask is not None
            else jax.tree.map(lambda _: True, grads)
        )

        new_params, new_mu, new_nu = {}, {}, {}
        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        treedef = jax.tree_util.tree_structure(grads)
        p_l, g_l = jax.tree.leaves(params), jax.tree.leaves(grads)
        m_l, v_l = jax.tree.leaves(state.mu), jax.tree.leaves(state.nu)
        la_l, wm_l, tm_l = jax.tree.leaves(la), jax.tree.leaves(wm), jax.tree.leaves(tm)

        xs, ms, vs = [], [], []
        for p, g, m, v, axis, wd_on, tr_on in zip(
            p_l, g_l, m_l, v_l, la_l, wm_l, tm_l
        ):
            axis = 0 if axis == 0 else None
            x2, m2, v2 = lamb_update(
                p, g, m, v, count, lr_t,
                lr=1.0, b1=b1, b2=b2, eps=eps,
                weight_decay=weight_decay if wd_on else 0.0,
                phi_lo=None if phi_bounds is None else phi_bounds[0],
                phi_hi=None if phi_bounds is None else phi_bounds[1],
                layer_axis=axis, apply_trust=bool(tr_on),
                interpret=interpret,
            )
            xs.append(x2)
            ms.append(m2)
            vs.append(v2)

        new_params = jax.tree_util.tree_unflatten(treedef, xs)
        new_state = FusedLambState(
            count,
            jax.tree_util.tree_unflatten(treedef, ms),
            jax.tree_util.tree_unflatten(treedef, vs),
        )
        # Return *updates* (delta) so apply_updates composes like other opts.
        updates = jax.tree.map(
            lambda new, old: (new.astype(jnp.float32) - old.astype(jnp.float32)).astype(old.dtype),
            new_params, params,
        )
        return updates, new_state

    return GradientTransformation(init, update)


def flash_sdpa(
    q: jnp.ndarray,  # (B, S, H, D)  model layout
    k: jnp.ndarray,  # (B, T, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """Flash attention on the model's (B, S, H, D) layout with GQA."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    if h != hkv:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
