from repro.common.pytree import (
    global_norm,
    merge_trees,
    path_mask,
    tree_bytes,
    tree_leaves_with_paths,
    tree_map_with_path,
    tree_paths,
    tree_size,
    tree_zeros_like,
)

__all__ = [
    "global_norm",
    "merge_trees",
    "path_mask",
    "tree_bytes",
    "tree_leaves_with_paths",
    "tree_map_with_path",
    "tree_paths",
    "tree_size",
    "tree_zeros_like",
]
