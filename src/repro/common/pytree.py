"""Path-aware pytree utilities.

The whole framework represents parameters as nested dicts of arrays.  These
helpers provide path-labelled mapping (used for weight-decay masks, trust-ratio
exclusion lists, per-layer diagnostics) without depending on flax.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

PyTree = Any


def path_str(path) -> str:
    """Render a jax key-path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: PyTree, *rest: PyTree) -> PyTree:
    """Map fn(path_string, leaf, *rest_leaves) over a pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x, *r: fn(path_str(kp), x, *r), tree, *rest
    )


def tree_paths(tree: PyTree) -> PyTree:
    """Tree of the same structure whose leaves are their own path strings."""
    return jax.tree_util.tree_map_with_path(lambda kp, _: path_str(kp), tree)


def path_mask(tree: PyTree, patterns, *, default: bool = False) -> PyTree:
    """Boolean mask tree: leaf True iff any regex in `patterns` matches its path.

    With default=True semantics inverted (True unless matched).
    """
    compiled = [re.compile(p) for p in patterns]

    def match(path: str, _):
        hit = any(c.search(path) for c in compiled)
        return (not hit) if default else hit

    return tree_map_with_path(match, tree)


def tree_leaves_with_paths(tree: PyTree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(kp), leaf) for kp, leaf in flat]


def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        dt = jnp.dtype(x.dtype)
        total += int(x.size) * dt.itemsize
    return total


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def merge_trees(base: Mapping, override: Mapping) -> dict:
    """Recursive dict merge (override wins)."""
    out = dict(base)
    for k, v in override.items():
        if k in out and isinstance(out[k], Mapping) and isinstance(v, Mapping):
            out[k] = merge_trees(out[k], v)
        else:
            out[k] = v
    return out


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.array(0.0)
