"""Data pipeline: prefetch, device_put with sharding, stage-aware resizing.

A thin production-style wrapper over the deterministic synthetic sources:
  * host-sharded batches (each host generates only its slice)
  * optional device placement with a NamedSharding (global arrays)
  * stage switching (mixed-batch training changes (batch, seq) mid-run)

Placement: pass either an explicit ``sharding`` (applied to every leaf) or a
``mesh`` — with a mesh, batches are split over its data axes
(``sharding.batch_sharding``), which is exactly the layout the sharded train
step declares via ``in_shardings``, so the jit boundary never reshards.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import batch_iterator
from repro.sharding.axes import batch_axes, dp_size
from repro.sharding.placement import batch_sharding


class DataPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        *,
        seed: int = 0,
        sharding=None,
        mesh=None,
        prefetch: int = 2,
    ):
        if mesh is not None and sharding is None:
            dp = dp_size(mesh)
            if batch % dp:
                raise ValueError(
                    f"batch {batch} is not divisible by the mesh's "
                    f"data-parallel size {dp} (axes {batch_axes(mesh)})"
                )
            sharding = batch_sharding(mesh)
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.sharding = sharding
        self.prefetch = prefetch
        self._it = batch_iterator(
            cfg, batch, seq, seed=seed,
            host_index=jax.process_index(), host_count=jax.process_count(),
        )
        self._buf: collections.deque = collections.deque()

    def _fill(self):
        while len(self._buf) < self.prefetch:
            b = next(self._it)
            if self.sharding is not None:
                b = jax.tree.map(
                    lambda x, s=self.sharding: jax.device_put(x, s), b
                )
            self._buf.append(b)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        self._fill()
        return self._buf.popleft()

    def with_stage(self, batch: int, seq: int) -> "DataPipeline":
        """New pipeline for a mixed-batch stage (fresh shapes, same source)."""
        return DataPipeline(
            self.cfg, batch, seq, seed=self.seed,
            sharding=self.sharding, prefetch=self.prefetch,
        )
