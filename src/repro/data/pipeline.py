"""Data pipeline: prefetch, device_put with sharding, stage-aware resizing.

A thin production-style wrapper over the deterministic synthetic sources:
  * host-sharded batches (each host generates only its slice)
  * optional device placement with a NamedSharding (global arrays)
  * stage switching (mixed-batch training changes (batch, seq) mid-run)
"""
from __future__ import annotations

import collections
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import batch_iterator


class DataPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        *,
        seed: int = 0,
        sharding=None,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.sharding = sharding
        self.prefetch = prefetch
        self._it = batch_iterator(
            cfg, batch, seq, seed=seed,
            host_index=jax.process_index(), host_count=jax.process_count(),
        )
        self._buf: collections.deque = collections.deque()

    def _fill(self):
        while len(self._buf) < self.prefetch:
            b = next(self._it)
            if self.sharding is not None:
                b = jax.tree.map(
                    lambda x, s=self.sharding: jax.device_put(x, s), b
                )
            self._buf.append(b)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        self._fill()
        return self._buf.popleft()

    def with_stage(self, batch: int, seq: int) -> "DataPipeline":
        """New pipeline for a mixed-batch stage (fresh shapes, same source)."""
        return DataPipeline(
            self.cfg, batch, seq, seed=self.seed,
            sharding=self.sharding, prefetch=self.prefetch,
        )
