from repro.data.pipeline import DataPipeline
from repro.data.synthetic import (
    SyntheticLM,
    audio_batch,
    batch_iterator,
    lm_batch,
    make_batch,
    vlm_batch,
)

__all__ = [
    "DataPipeline",
    "SyntheticLM",
    "audio_batch",
    "batch_iterator",
    "lm_batch",
    "make_batch",
    "vlm_batch",
]
