"""Async-dispatch-aware span timers.

JAX dispatch is asynchronous: ``t1 - t0`` around a jit'd call measures
Python dispatch, not device work, so naive per-step timing *lies* — the
first timed step absorbs compilation and every later one reads near zero
while the device queue runs behind.  A :class:`SpanRecorder` span therefore
synchronizes only at its *boundaries*: ``block_until_ready`` on the
arrays handed to ``sync=`` when the span opens (drain the queue of prior
work) and on whatever the body registered via ``handle.block_on(...)``
when it closes (wait for the span's own work).  Everything dispatched
inside the span overlaps freely, so timing k steps costs two syncs, not k.

Two usage shapes share one accumulator:

* scoped::

      with spans.span("step", sync=state) as sp:
          for _ in range(k):
              state, metrics = step(state, batch)
          sp.block_on(state)
          sp.count = k

* phase-style (loop bodies that decide boundaries mid-iteration)::

      spans.start("step", sync=state)
      ...
      spans.stop("step", sync=(state, metrics), count=k)

Each closed span is one observation (``seconds`` / ``count`` items);
``summary()`` folds observations into count/total/mean/p50/max per name,
and a wired :class:`~repro.telemetry.events.EventLog` receives one
``span`` event per close.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.telemetry.events import EventLog


class SpanHandle:
    """Mutable per-span state the body can attach results to."""

    def __init__(self, count: int = 1):
        self.count = count
        self._pending: List[Any] = []

    def block_on(self, tree: Any) -> Any:
        """Register arrays the span must wait for at close (returns them)."""
        self._pending.append(tree)
        return tree


class SpanRecorder:
    """Accumulates named span observations; optionally emits span events."""

    def __init__(self, log: Optional[EventLog] = None):
        self.log = log
        self._obs: Dict[str, List[tuple]] = {}  # name -> [(seconds, count)]
        self._open: Dict[str, float] = {}

    # -- core ----------------------------------------------------------------
    def observe(self, name: str, seconds: float, count: int = 1) -> None:
        """Record one closed span (the single accumulation point)."""
        self._obs.setdefault(name, []).append((float(seconds), int(count)))
        if self.log is not None:
            self.log.emit("span", name=name, seconds=float(seconds),
                          count=int(count))

    @staticmethod
    def _sync(tree: Any) -> None:
        if tree is not None:
            import jax

            jax.block_until_ready(tree)

    # -- scoped --------------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, *, sync: Any = None, count: int = 1):
        self._sync(sync)
        handle = SpanHandle(count)
        t0 = time.perf_counter()
        try:
            yield handle
        finally:
            for tree in handle._pending:
                self._sync(tree)
            self.observe(name, time.perf_counter() - t0, handle.count)

    # -- phase-style ---------------------------------------------------------
    def start(self, name: str, *, sync: Any = None) -> None:
        """Open (or re-open) a named span; syncs, then stamps t0."""
        self._sync(sync)
        self._open[name] = time.perf_counter()

    def stop(self, name: str, *, sync: Any = None, count: int = 1) -> float:
        """Close a named span opened by :meth:`start`; returns seconds."""
        t0 = self._open.pop(name, None)
        if t0 is None:
            raise ValueError(f"span {name!r} was never started")
        self._sync(sync)
        dt = time.perf_counter() - t0
        self.observe(name, dt, count)
        return dt

    # -- aggregation ---------------------------------------------------------
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name {count, total_s, mean_s, p50_s, max_s}; ``mean_s`` is
        per *item* (seconds/count), so a 10-step span contributes per-step
        time — the number to compare across log cadences."""
        out = {}
        for name, obs in self._obs.items():
            secs = np.array([s for s, _ in obs])
            items = np.array([c for _, c in obs])
            per_item = secs / np.maximum(items, 1)
            out[name] = {
                "count": int(items.sum()),
                "total_s": float(secs.sum()),
                "mean_s": float(secs.sum() / max(items.sum(), 1)),
                "p50_s": float(np.percentile(per_item, 50)),
                "max_s": float(per_item.max()),
            }
        return out
