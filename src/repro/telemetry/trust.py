"""Per-layer trust-ratio recorder (host side of the App. H diagnostics).

The device side lives in the train step: with
``TrainConfig.record_trust_ratios`` the step returns, under
``metrics["telemetry/per_layer"]``, three pytrees shaped like the params —
``trust_ratio`` (the ratio the optimizer actually applied: threaded out of
the fused-LAMB kernels as an aux output, recomputed as
``phi(||x||)/||Δx||`` on the unfused transform chain), ``param_norm`` and
``update_norm``, each a per-layer-slice vector on stacked leaves.  That
stays on device, jit-compatible, until the Trainer's log step fetches the
whole metrics pytree in its one ``device_get``.

This module is what happens after the fetch: :class:`TrustRecorder` names
every leaf, histograms the ratios on fixed log-spaced bins (the paper's
Figures 9–14 span ~1e-3…30, so ratios are compared on a log axis), emits a
``trust_ratios`` event per logged step, and keeps running per-leaf
aggregates for the run report.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.events import EventLog

# Reserved metrics key the train step parks the per-layer pytrees under and
# the Trainer pops before building its scalar history.
PER_LAYER_KEY = "telemetry/per_layer"

# log10-spaced histogram edges covering the trust-ratio range the paper
# plots (App. H): 1e-4 … 1e2.
HIST_EDGES = np.logspace(-4.0, 2.0, 25)


def leaf_names(tree: Any) -> List[str]:
    """Stable dotted names for a pytree's leaves (param paths)."""
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))
            parts.append(str(key))
        names.append(".".join(parts) if parts else "param")
    return names


def named_leaves(tree: Any) -> List[Tuple[str, np.ndarray]]:
    import jax

    return list(zip(leaf_names(tree), map(np.atleast_1d, jax.tree.leaves(tree))))


class TrustRecorder:
    """Folds per-layer records into histograms + per-leaf running stats.

    ``record`` consumes one logged step's host-side records (the popped
    ``telemetry/per_layer`` pytrees) and is cheap: vectors are n_layers
    long, not parameter-sized.
    """

    def __init__(self, log: Optional[EventLog] = None,
                 edges: np.ndarray = HIST_EDGES):
        self.log = log
        self.edges = np.asarray(edges, np.float64)
        self._hist = np.zeros(len(self.edges) - 1, np.int64)
        self._per_leaf: Dict[str, Dict[str, float]] = {}
        self.steps_recorded = 0

    def record(self, step: int, records: Dict[str, Any]) -> Dict[str, Any]:
        """Ingest one step's records; returns the emitted per-leaf layers dict."""
        ratios = named_leaves(records["trust_ratio"])
        pnorms = dict(named_leaves(records.get("param_norm", {})))
        unorms = dict(named_leaves(records.get("update_norm", {})))

        layers: Dict[str, Dict[str, Any]] = {}
        all_r = []
        for name, r in ratios:
            r = np.asarray(r, np.float64).reshape(-1)
            all_r.append(r)
            entry = {
                "min": float(r.min()),
                "mean": float(r.mean()),
                "max": float(r.max()),
                "per_layer": [float(x) for x in r],
            }
            if name in pnorms:
                entry["param_norm"] = [float(x) for x in
                                       np.asarray(pnorms[name]).reshape(-1)]
            if name in unorms:
                entry["update_norm"] = [float(x) for x in
                                        np.asarray(unorms[name]).reshape(-1)]
            layers[name] = entry
            agg = self._per_leaf.setdefault(
                name, {"min": np.inf, "max": -np.inf, "sum": 0.0, "n": 0})
            agg["min"] = min(agg["min"], entry["min"])
            agg["max"] = max(agg["max"], entry["max"])
            agg["sum"] += float(r.sum())
            agg["n"] += r.size

        flat = np.concatenate(all_r) if all_r else np.zeros(0)
        counts, _ = np.histogram(flat, bins=self.edges)
        self._hist += counts
        self.steps_recorded += 1
        if self.log is not None:
            self.log.emit(
                "trust_ratios", step=int(step), layers=layers,
                hist={"edges": self.edges.tolist(),
                      "counts": counts.tolist()},
            )
        return layers

    def summary(self) -> Dict[str, Any]:
        """Run-level aggregate for the report (empty dict when never fed)."""
        if not self.steps_recorded:
            return {}
        return {
            "steps_recorded": self.steps_recorded,
            "hist": {"edges": self.edges.tolist(),
                     "counts": self._hist.tolist()},
            "per_leaf": {
                name: {"min": agg["min"], "max": agg["max"],
                       "mean": agg["sum"] / max(agg["n"], 1)}
                for name, agg in self._per_leaf.items()
            },
        }
