"""Unified telemetry: structured events, span timers, trust-ratio
recording, and the regression-gated run report.

See docs/observability.md for the walkthrough.
"""
from repro.telemetry.events import (
    EVENT_TYPES,
    SCHEMA_VERSION,
    EventLog,
    config_hash,
    read_events,
    run_provenance,
    validate_event,
)
from repro.telemetry.report import Check, CompareResult, RunReport
from repro.telemetry.spans import SpanRecorder
from repro.telemetry.trust import HIST_EDGES, PER_LAYER_KEY, TrustRecorder, leaf_names

__all__ = [
    "Check",
    "CompareResult",
    "EVENT_TYPES",
    "EventLog",
    "HIST_EDGES",
    "PER_LAYER_KEY",
    "RunReport",
    "SCHEMA_VERSION",
    "SpanRecorder",
    "TrustRecorder",
    "config_hash",
    "leaf_names",
    "read_events",
    "run_provenance",
    "validate_event",
]
