"""RunReport: fold an event log (+ ``BENCH_*.json``) into one comparable
report with an MLPerf-style regression gate.

The Nado et al. "reality check" point (PAPERS.md): a large-batch optimizer
claim is only credible when the metrics travel *with* their provenance —
what was tuned, what schedule ran, what hardware.  ``RUN_REPORT.json`` is
that unit here.  ``RunReport.from_events`` replays a structured event log
(``telemetry.events``) into sections — provenance, train (steps, final
metrics, span-timed step seconds), trust-ratio summaries, serve, bench —
and ``compare(baseline, tolerances)`` is the regression gate CI runs
against a committed baseline: presence checks for schema/sections, relative
tolerances for numbers (the reframe-mlperf idiom — a benchmark that cannot
fail is a demo, not a gate).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.telemetry.events import (
    SCHEMA_VERSION,
    EventLog,
    _jsonable,
    read_events,
)

_MISSING = object()


def _get_path(d: Any, dotted: str):
    """Walk ``a.b.c`` through nested dicts; _MISSING when absent."""
    cur = d
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return _MISSING
        cur = cur[part]
    return cur


@dataclasses.dataclass
class Check:
    key: str
    status: str  # ok | missing | mismatch | regressed
    detail: str = ""


@dataclasses.dataclass
class CompareResult:
    ok: bool
    checks: List[Check]

    def failures(self) -> List[Check]:
        return [c for c in self.checks if c.status != "ok"]

    def render(self) -> str:
        lines = [f"{c.status:10s} {c.key}  {c.detail}".rstrip()
                 for c in self.checks]
        verdict = "PASS" if self.ok else "FAIL"
        return "\n".join(lines + [f"compare: {verdict} "
                                  f"({len(self.failures())} failures)"])


class RunReport:
    """One run's folded report: ``.report`` is a plain JSON-ready dict."""

    def __init__(self, report: Dict[str, Any]):
        self.report = report

    # ------------------------------------------------------------------
    @classmethod
    def from_events(
        cls,
        events: Union[str, Path, EventLog, List[dict]],
        *,
        bench_dir: Optional[Union[str, Path]] = None,
    ) -> "RunReport":
        """Fold an event log (path / memory EventLog / event list) into a
        report; ``bench_dir`` additionally folds every ``BENCH_*.json``
        found there (each keyed by its suffix, provenance-stamped or not).
        """
        if isinstance(events, EventLog):
            evs = list(events.events)
        elif isinstance(events, (str, Path)):
            evs = read_events(events)
        else:
            evs = list(events)

        by_type: Dict[str, List[dict]] = {}
        for ev in evs:
            by_type.setdefault(ev["event"], []).append(ev)

        report: Dict[str, Any] = {
            "schema_version": SCHEMA_VERSION,
            "events": {
                "count": len(evs),
                "types": {k: len(v) for k, v in sorted(by_type.items())},
            },
        }
        if by_type.get("run_start"):
            report["provenance"] = by_type["run_start"][0].get("provenance", {})
        if by_type.get("run_end"):
            end = by_type["run_end"][-1]
            report["run_end"] = {k: v for k, v in end.items()
                                 if k not in ("event", "seq")}
            if "status" in end:
                report["status"] = end["status"]

        steps = by_type.get("step", [])
        if steps:
            last = steps[-1]
            train: Dict[str, Any] = {
                "logged_steps": len(steps),
                "steps": int(last["step"]),
                "final": dict(last.get("metrics", {})),
            }
            if "examples_seen" in last:
                train["examples_seen"] = int(last["examples_seen"])
            if "wall_s" in last:
                train["wall_s"] = float(last["wall_s"])
            report["train"] = train

        spans = by_type.get("span", [])
        if spans:
            agg: Dict[str, List[tuple]] = {}
            for ev in spans:
                agg.setdefault(ev["name"], []).append(
                    (float(ev["seconds"]), int(ev.get("count", 1))))
            report["spans"] = {
                name: {
                    "count": int(sum(c for _, c in obs)),
                    "total_s": float(sum(s for s, _ in obs)),
                    "mean_s": float(sum(s for s, _ in obs)
                                    / max(sum(c for _, c in obs), 1)),
                    "max_s": float(max(s / max(c, 1) for s, c in obs)),
                }
                for name, obs in agg.items()
            }

        trust = by_type.get("trust_ratios", [])
        if trust:
            last = trust[-1]
            hist = np.zeros(0)
            edges: List[float] = []
            for ev in trust:
                h = ev.get("hist", {})
                counts = np.asarray(h.get("counts", []), np.int64)
                if counts.size:
                    hist = counts if hist.size == 0 else hist + counts
                    edges = h.get("edges", edges)
            report["trust_ratios"] = {
                "steps_recorded": len(trust),
                "last_step": int(last["step"]),
                "per_leaf": {
                    name: {k: entry[k] for k in ("min", "mean", "max")}
                    for name, entry in last["layers"].items()
                },
                "hist": {"edges": edges, "counts": hist.tolist()},
            }

        stages = by_type.get("stage_start", [])
        if stages:
            report["stages"] = [
                {k: v for k, v in ev.items() if k not in ("event", "seq", "t")}
                for ev in stages
            ]
        ckpts = by_type.get("checkpoint", [])
        if ckpts:
            section: Dict[str, Any] = {
                "count": len(ckpts),
                "last_step": int(ckpts[-1]["step"]),
            }
            # async saves carry their phase timings: what the step loop paid
            # (snapshot + blocked) vs what overlapped with compute (write)
            asyncs = [ev for ev in ckpts if ev.get("mode") == "async"]
            if asyncs:
                def _mean(key):
                    return float(np.mean([float(ev[key]) for ev in asyncs]))

                section["async"] = {
                    "count": len(asyncs),
                    "snapshot_s_mean": _mean("snapshot_s"),
                    "blocked_s_mean": _mean("blocked_s"),
                    "blocked_s_max": float(
                        max(float(ev["blocked_s"]) for ev in asyncs)),
                    "write_s_mean": _mean("write_s"),
                    "write_s_total": float(
                        sum(float(ev["write_s"]) for ev in asyncs)),
                }
            report["checkpoints"] = section

        # fault tolerance: skip-step guard trips, supervisor rollbacks,
        # preemption saves — the counts the acceptance harness asserts on
        skips = by_type.get("nonfinite_step", [])
        rollbacks = by_type.get("rollback", [])
        preempts = by_type.get("preempt", [])
        if skips or rollbacks or preempts:
            ft: Dict[str, Any] = {
                "skipped_steps": int(sum(int(ev.get("count", 1))
                                         for ev in skips)),
                "rollbacks": len(rollbacks),
                "preempts": len(preempts),
            }
            if rollbacks:
                last = rollbacks[-1]
                ft["last_rollback"] = {
                    "step": int(last["step"]),
                    "from_step": int(last["from_step"]),
                    "reason": last["reason"],
                }
            if preempts:
                ft["last_preempt_step"] = int(preempts[-1]["step"])
            report["fault_tolerance"] = ft

        resumes = by_type.get("resume", [])
        if resumes:
            report["resume"] = {
                "count": len(resumes),
                "step": int(resumes[-1]["step"]),
            }

        sreqs = by_type.get("serve_request", [])
        sstats = by_type.get("serve_stats", [])
        if sreqs or sstats:
            serve: Dict[str, Any] = {
                "requests": len(sreqs),
                "dropped": sum(1 for ev in sreqs if ev.get("dropped")),
            }
            # disjoint terminal-state counts (each serve_request event is
            # one request's single terminal record, so these sum to
            # `requests`) plus the reliability lifecycle counters the serve
            # fault-injection gate asserts on
            statuses = [ev.get("status") for ev in sreqs]
            if any(s is not None for s in statuses):
                serve["by_status"] = {
                    s: statuses.count(s)
                    for s in ("completed", "shed", "timed_out", "failed")
                }
            lifecycle = {
                "sheds": len(by_type.get("serve_shed", [])),
                "timeouts": len(by_type.get("serve_timeout", [])),
                "retries": len(by_type.get("serve_retry", [])),
                "quarantines": len(by_type.get("serve_quarantine", [])),
                "degraded_transitions": len(by_type.get("serve_degraded", [])),
                "drains": len(by_type.get("serve_drain", [])),
            }
            if any(lifecycle.values()):
                serve["lifecycle"] = lifecycle
            if sstats:
                serve["stats"] = {
                    k: v for k, v in sstats[-1].items()
                    if k not in ("event", "seq", "t")
                }
            report["serve"] = serve

        bench: Dict[str, Any] = {}
        for ev in by_type.get("bench_result", []):
            bench[ev["name"]] = {
                k: v for k, v in ev.items() if k not in ("event", "seq", "t", "name")
            }
        if bench_dir is not None:
            for p in sorted(Path(bench_dir).glob("BENCH_*.json")):
                key = p.stem[len("BENCH_"):]
                try:
                    bench.setdefault(key, {})["json"] = json.loads(p.read_text())
                except (OSError, json.JSONDecodeError) as e:
                    bench.setdefault(key, {})["error"] = f"{type(e).__name__}: {e}"
        if bench:
            report["bench"] = bench
        return cls(report)

    # ------------------------------------------------------------------
    @classmethod
    def load(cls, path: Union[str, Path]) -> "RunReport":
        return cls(json.loads(Path(path).read_text()))

    def write(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.report, indent=2, default=_jsonable))
        return p

    # ------------------------------------------------------------------
    def compare(
        self,
        baseline: Union["RunReport", Dict[str, Any]],
        tolerances: Dict[str, Optional[float]],
        *,
        require_sections: bool = True,
    ) -> CompareResult:
        """Regression-gate this report against ``baseline``.

        ``tolerances`` maps dotted key paths to a relative tolerance —
        ``None`` means *presence only* (the key must exist in this report;
        timing-ish values that legitimately drift), ``0.0`` means exact
        equality, ``r`` means ``|new - base| <= r * max(|base|, 1e-12)``.
        With ``require_sections`` every top-level section of the baseline
        must be present here (schema check).  A key missing from the
        *baseline* is checked for presence only — new reports may grow
        sections old baselines lack without failing the gate.
        """
        base = baseline.report if isinstance(baseline, RunReport) else baseline
        checks: List[Check] = []

        if require_sections:
            for section in base:
                status = "ok" if section in self.report else "missing"
                checks.append(Check(f"section:{section}", status))

        for key, tol in sorted(tolerances.items()):
            new = _get_path(self.report, key)
            ref = _get_path(base, key)
            if new is _MISSING:
                checks.append(Check(key, "missing", "absent from report"))
                continue
            if tol is None or ref is _MISSING:
                checks.append(Check(key, "ok", "present"))
                continue
            if isinstance(new, (int, float)) and isinstance(ref, (int, float)):
                diff = abs(float(new) - float(ref))
                bound = tol * max(abs(float(ref)), 1e-12)
                if diff <= bound:
                    checks.append(Check(
                        key, "ok", f"{new} vs {ref} (tol {tol})"))
                else:
                    checks.append(Check(
                        key, "regressed",
                        f"{new} vs baseline {ref} exceeds rel tol {tol}"))
            else:
                status = "ok" if new == ref else "mismatch"
                checks.append(Check(key, status, f"{new!r} vs {ref!r}"))

        return CompareResult(all(c.status == "ok" for c in checks), checks)
