"""Structured event log: typed JSONL events + run provenance.

One schema replaces the three ad-hoc logging paths (Trainer prints,
serve ``serving_stats`` dicts, per-benchmark ``BENCH_*.json`` blobs): every
subsystem emits typed events through an :class:`EventLog`, and
``telemetry.report.RunReport`` folds a log back into one comparable
``RUN_REPORT.json``.

Events are append-only JSON lines ``{"event": type, "seq": n, "t": wall,
...fields}``.  The event *types* are closed (:data:`EVENT_TYPES` — unknown
types are a bug, not a forward-compat feature) but each type's payload is
open beyond its :data:`REQUIRED_FIELDS`, so emitters can attach context
without schema churn.

The default sink is *null*: an ``EventLog()`` with no path and no buffer is
disabled, ``emit`` returns immediately without touching its arguments, and
every integration point (Trainer, ContinuousEngine, launchers) treats that
as "telemetry off" — the hot loops do no extra device syncs and history
stays bit-identical (tested).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import subprocess
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

import numpy as np

SCHEMA_VERSION = 1

EVENT_TYPES = frozenset({
    "run_start",      # provenance: git sha, jax version, device, mesh, config hash
    "stage_start",    # mixed-batch stage boundary
    "step",           # logged training step: metrics + span-timed step seconds
    "span",           # one closed span: name, seconds, count
    "trust_ratios",   # per-layer trust-ratio/norm summaries at a logged step
    "checkpoint",     # checkpoint written (async saves add snapshot/write timings)
    "resume",         # training resumed from a persisted checkpoint
    "serve_request",  # one request's terminal lifecycle record
    "serve_stats",    # aggregate serving stats for one generate() run
    "serve_shed",     # admission control rejected a request (reason says why)
    "serve_timeout",  # request blew its latency budget (queue or decode)
    "serve_retry",    # transient failure: request requeued for another attempt
    "serve_quarantine",  # corrupted slot withheld from the free list
    "serve_degraded", # stall watchdog toggled degraded admissions
    "serve_drain",    # graceful drain started: admissions stopped
    "bench_result",   # one benchmark suite's result
    "nonfinite_step", # in-jit guard skipped step(s): non-finite loss/grads
    "rollback",       # supervisor restored an earlier checkpoint after a trip
    "preempt",        # SIGTERM/SIGINT caught: grace-window save + clean stop
    "run_end",        # terminal event (carries an explicit status)
})

# minimum payload per type; extra fields are allowed and preserved
REQUIRED_FIELDS: Dict[str, tuple] = {
    "run_start": ("provenance",),
    "stage_start": ("stage", "name"),
    "step": ("step",),
    "span": ("name", "seconds"),
    "trust_ratios": ("step", "layers"),
    "checkpoint": ("step", "path"),
    "resume": ("step", "path"),
    "serve_request": ("rid",),
    "serve_stats": (),
    "serve_shed": ("rid", "reason"),
    "serve_timeout": ("rid",),
    "serve_retry": ("rid", "attempt"),
    "serve_quarantine": ("slot", "rid"),
    "serve_degraded": ("active",),
    "serve_drain": ("queued", "in_flight"),
    "bench_result": ("name",),
    "nonfinite_step": ("step", "count"),
    "rollback": ("step", "from_step", "reason"),
    "preempt": ("step", "signal"),
    "run_end": (),
}


def _jsonable(obj: Any):
    """JSON encoder default: numpy scalars/arrays and paths degrade cleanly."""
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, Path):
        return str(obj)
    if hasattr(obj, "tolist"):  # jax arrays without importing jax here
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def validate_event(ev: Dict[str, Any]) -> None:
    """Raise ValueError unless ``ev`` is a well-formed typed event."""
    etype = ev.get("event")
    if etype not in EVENT_TYPES:
        raise ValueError(
            f"unknown event type {etype!r}; known: {sorted(EVENT_TYPES)}"
        )
    missing = [f for f in REQUIRED_FIELDS[etype] if f not in ev]
    if missing:
        raise ValueError(f"event {etype!r} missing required fields {missing}")


class EventLog:
    """Append-only JSONL event emitter with a zero-overhead null default.

    Three modes:

    * ``EventLog()`` — **null sink** (default everywhere): ``enabled`` is
      False and ``emit`` is a no-op that never serializes its arguments.
    * ``EventLog(path)`` / ``EventLog.to_dir(dir)`` — append JSON lines to
      ``path`` (created, parents included), flushed per event.
    * ``EventLog.memory()`` — buffer events in ``self.events`` (tests,
      benchmark sweeps that fold straight into a report).

    Every emitted event is validated against :data:`EVENT_TYPES` /
    :data:`REQUIRED_FIELDS` and stamped with a monotonically increasing
    ``seq`` and a wall-clock ``t``.
    """

    def __init__(self, path: Optional[Union[str, Path]] = None,
                 *, buffer: bool = False):
        self.path = Path(path) if path is not None else None
        self.events: List[Dict[str, Any]] = []
        self._buffer = buffer
        self._seq = 0
        self._fh = None
        # emit must be thread-safe: the AsyncCheckpointer's background
        # writer emits checkpoint events while the step loop emits its own
        self._lock = threading.Lock()

    @classmethod
    def to_dir(cls, directory: Union[str, Path],
               name: str = "events.jsonl") -> "EventLog":
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        return cls(d / name)

    @classmethod
    def memory(cls) -> "EventLog":
        return cls(buffer=True)

    @property
    def enabled(self) -> bool:
        return self.path is not None or self._buffer

    def emit(self, event: str, **fields) -> Optional[Dict[str, Any]]:
        """Validate, stamp and write one event; no-op when disabled."""
        if not self.enabled:
            return None
        with self._lock:
            ev = {"event": event, "seq": self._seq, "t": time.time(), **fields}
            validate_event(ev)
            self._seq += 1
            if self._buffer:
                self.events.append(ev)
            if self.path is not None:
                if self._fh is None:
                    self.path.parent.mkdir(parents=True, exist_ok=True)
                    self._fh = self.path.open("a")
                self._fh.write(json.dumps(ev, default=_jsonable) + "\n")
                self._fh.flush()
            return ev

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "EventLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """Load and validate a JSONL event log (schema round-trip)."""
    events = []
    for line in Path(path).read_text().splitlines():
        if not line.strip():
            continue
        ev = json.loads(line)
        validate_event(ev)
        events.append(ev)
    return events


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=10,
        )
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def config_hash(*configs) -> str:
    """Stable sha256 over one or more (frozen-dataclass) configs."""
    blobs = []
    for c in configs:
        if c is None:
            continue
        d = dataclasses.asdict(c) if dataclasses.is_dataclass(c) else c
        blobs.append(json.dumps(d, sort_keys=True, default=str))
    return hashlib.sha256("|".join(blobs).encode()).hexdigest()[:16]


def run_provenance(*, timestamp: Optional[float] = None, mesh=None,
                   configs: tuple = ()) -> Dict[str, Any]:
    """The provenance block every run/report carries (MLPerf-style).

    ``timestamp`` is passed in by the caller (benchmarks stamp their own so
    a sweep's suites share one); ``mesh`` is a ``jax.sharding.Mesh`` or
    None; ``configs`` are hashed, not embedded, so reports stay diffable.
    """
    import jax  # deferred: keep module importable before backend choice

    devices = jax.devices()
    prov: Dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "timestamp": time.time() if timestamp is None else float(timestamp),
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
    }
    try:
        import jaxlib

        prov["jaxlib_version"] = jaxlib.version.__version__
    except Exception:
        prov["jaxlib_version"] = "unknown"
    if mesh is not None:
        prov["mesh"] = {str(k): int(v) for k, v in dict(mesh.shape).items()}
    if configs:
        prov["config_hash"] = config_hash(*configs)
    return prov
