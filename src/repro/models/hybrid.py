"""Jamba-style hybrid model: Mamba + attention interleaved 7:1, MoE every
other layer (arXiv:2403.19887).

The 8-layer period is the scan unit: layers inside a period are heterogeneous
(one attention layer, the rest mamba; alternating MoE/MLP FFNs) so the period
body unrolls its 8 sub-layers while ``lax.scan`` runs over the 9 periods.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    abstract_kv_cache,
    attention,
    attention_defs,
    init_kv_cache,
)
from repro.models.layers.embeddings import embed, embed_defs, unembed, unembed_defs
from repro.models.layers.mamba import (
    abstract_mamba_state,
    init_mamba_state,
    mamba,
    mamba_defs,
)
from repro.models.layers.mlp import mlp, mlp_defs
from repro.models.layers.moe import moe, moe_defs
from repro.models.layers.norms import apply_norm, norm_defs


def _attn_index(cfg: ModelConfig) -> int:
    # place the attention layer mid-period (Jamba: 1 attn per 8 layers)
    return cfg.attn_period // 2


def _is_moe_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.n_experts > 0 and (i % cfg.moe_period_in_block == 1)


def _period_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    period: Dict[str, Any] = {}
    for i in range(cfg.attn_period):
        sub = {
            "ln1": norm_defs(d, cfg.norm_type),
            "ln2": norm_defs(d, cfg.norm_type),
            "mixer": attention_defs(cfg) if i == _attn_index(cfg) else mamba_defs(cfg),
        }
        if _is_moe_layer(cfg, i):
            sub["ffn_moe"] = moe_defs(cfg)
        else:
            sub["ffn"] = mlp_defs(d, cfg.d_ff, cfg.gated_mlp)
        period[f"sub{i}"] = sub
    return period


def hybrid_defs(cfg: ModelConfig) -> dict:
    n_groups = cfg.n_layers // cfg.attn_period
    return {
        "embed": embed_defs(cfg.vocab_size, cfg.d_model),
        "groups": nn.stack(_period_defs(cfg), n_groups),
        "final_norm": norm_defs(cfg.d_model, cfg.norm_type),
        "unembed": unembed_defs(cfg.d_model, cfg.vocab_size),
    }


def forward(
    params: dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    caches: Optional[dict] = None,
    decode: bool = False,
    positions: Optional[jnp.ndarray] = None,
    mamba_chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, jnp.ndarray]]:
    if mamba_chunk is None:
        mamba_chunk = cfg.mamba_chunk
    dtype = jnp.dtype(cfg.activation_dtype)
    x = embed(params["embed"], batch["tokens"], dtype)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    attn_i = _attn_index(cfg)

    def body(carry, xs):
        xc = carry
        gp, gcache = xs
        new_gcache: Dict[str, Any] = {}
        aux_acc: Dict[str, jnp.ndarray] = {}
        for i in range(cfg.attn_period):
            sub = gp[f"sub{i}"]
            key = f"sub{i}"
            h = apply_norm(sub["ln1"], xc, cfg.norm_type)
            if i == attn_i:
                out, nc = attention(
                    sub["mixer"], h, positions, cfg,
                    cache=(gcache or {}).get(key), decode=decode,
                )
            else:
                out, nc = mamba(
                    sub["mixer"], h, cfg,
                    state=(gcache or {}).get(key), decode=decode,
                    chunk=mamba_chunk,
                )
            if gcache is not None:
                new_gcache[key] = nc
            xc = xc + out
            h = apply_norm(sub["ln2"], xc, cfg.norm_type)
            if "ffn_moe" in sub:
                out, aux = moe(sub["ffn_moe"], h, cfg)
                for k, v in aux.items():
                    aux_acc[k] = aux_acc.get(k, 0.0) + v
            else:
                out = mlp(sub["ffn"], h, cfg)
            xc = xc + out
        return xc, (new_gcache if gcache is not None else None, aux_acc)

    if cfg.remat == "full":
        body = jax.checkpoint(body)

    if not cfg.scan_layers:
        n = jax.tree.leaves(params["groups"])[0].shape[0]
        ys = []
        for i in range(n):
            gp = jax.tree.map(lambda a: a[i], params["groups"])
            ci = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, y = body(x, (gp, ci))
            ys.append(y)
        new_caches = (
            None if caches is None
            else jax.tree.map(lambda *a: jnp.stack(a), *[y[0] for y in ys])
        )
        auxs = (
            {k: jnp.stack([y[1][k] for y in ys]) for k in ys[0][1]}
            if ys and ys[0][1] else {}
        )
    else:
        x, (new_caches, auxs) = jax.lax.scan(body, x, (params["groups"], caches))
    aux = {k: jnp.mean(v) for k, v in auxs.items()}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = unembed(x, params["unembed"])
    return logits, new_caches, aux


def make_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, abstract: bool, dtype=jnp.bfloat16
) -> dict:
    n_groups = cfg.n_layers // cfg.attn_period
    attn_i = _attn_index(cfg)
    group: Dict[str, Any] = {}
    for i in range(cfg.attn_period):
        key = f"sub{i}"
        if i == attn_i:
            group[key] = (
                abstract_kv_cache(batch, max_len, cfg, dtype)
                if abstract
                else init_kv_cache(batch, max_len, cfg, dtype)
            )
        else:
            group[key] = (
                abstract_mamba_state(batch, cfg, dtype)
                if abstract
                else init_mamba_state(batch, cfg, dtype)
            )
    if abstract:
        return jax.tree.map(
            lambda sds: jax.ShapeDtypeStruct((n_groups,) + sds.shape, sds.dtype), group
        )
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), group
    )
