"""Feed-forward blocks: gated (SwiGLU/GeGLU) and vanilla."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.sharding import shard_act


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_defs(d_model: int, d_ff: int, gated: bool) -> dict:
    defs = {
        "wi": nn.Param((d_model, d_ff), ("embed", "ff")),
        "wo": nn.Param((d_ff, d_model), ("ff", "embed")),
    }
    if gated:
        defs["wg"] = nn.Param((d_model, d_ff), ("embed", "ff"))
    return defs


def mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dtype = x.dtype
    act = _act(cfg.act_fn)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dtype))
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = shard_act(h, ("batch", "seq", "ff"))
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dtype))
    return shard_act(y, ("batch", "seq", "embed"))
