"""Mixture-of-Experts layer: top-k routing with static capacity.

Dispatch is scatter/gather based (O(T·k) index work, (E, C, d) expert
buffers) rather than the GShard one-hot-einsum form whose (T, E, C) dispatch
tensor is infeasible at 256 experts.  Expert weights are stacked on a leading
``experts`` axis which shards over the ``model`` mesh axis (expert
parallelism); the token scatter across expert shards lowers to the all-to-all
family of collectives under SPMD.

Routing follows the modern recipe (DeepSeek/granite): softmax router,
top-k, gates renormalized over the selected experts, Switch-style
load-balance auxiliary loss + optional router z-loss, optional shared
experts that every token passes through.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.mlp import _act, mlp, mlp_defs
from repro.sharding import shard_act


def moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": nn.Param((d, e), ("embed", "experts"), init="fan_in"),
        "wi": nn.Param((e, d, f), ("experts", "embed", "expert_ff")),
        "wg": nn.Param((e, d, f), ("experts", "embed", "expert_ff")),
        "wo": nn.Param((e, f, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared_experts:
        defs["shared"] = mlp_defs(d, cfg.n_shared_experts * f, gated=True)
    return defs


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Static per-expert capacity: ceil(cf * T * k / E), >= k."""
    c = int(cfg.capacity_factor * n_tokens * cfg.n_experts_per_tok / cfg.n_experts)
    return max(c, cfg.n_experts_per_tok)


def route(
    logits: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Top-k gates + aux losses.  logits: (T, E) fp32."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Switch-Transformer load-balance loss: E * <f_e * p_e>
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # (E,) mean router prob
    assigned = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)  # top-1 fraction
    fe = jnp.mean(assigned, axis=0)
    lb = e * jnp.sum(fe * me)
    aux = {"moe_lb_loss": lb, "moe_max_prob": jnp.max(me)}
    if cfg.router_z_coef:
        aux["moe_z_loss"] = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, idx, aux


def moe(
    p: dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, S, d) → (B, S, d), aux-loss dict."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.n_experts_per_tok, cfg.n_experts
    c = capacity(t, cfg)
    dtype = x.dtype
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, idx, aux = route(logits, cfg)

    # position of each (token, slot) within its expert, in flat assignment order
    flat_e = idx.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                  # rank within expert
    pos = jnp.sum(pos * onehot, axis=-1)                       # (T*k,)
    keep = pos < c
    dest = jnp.where(keep, flat_e * c + pos, e * c)            # OOB → dropped

    token_id = jnp.repeat(jnp.arange(t), k)                    # (T*k,)
    buf = jnp.zeros((e * c, d), dtype)
    buf = buf.at[dest].set(xf[token_id], mode="drop")
    buf = shard_act(buf.reshape(e, c, d), ("experts", None, "embed"))

    # expert FFN (stacked einsum; experts axis sharded on model)
    act = _act(cfg.act_fn)
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dtype))
    hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype))
    h = act(hg) * hi
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype))
    y = y.reshape(e * c, d)

    # combine: gather each slot's output back, weighted by its gate
    yk = jnp.where(keep[:, None], y.at[dest, :].get(mode="fill", fill_value=0.0), 0.0)
    out = jnp.sum(
        (yk * gates.reshape(-1, 1).astype(dtype)).reshape(t, k, d), axis=1
    )

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux["moe_drop_fraction"] = dropped

    if "shared" in p:
        out = out + mlp(p["shared"], xf[:, None, :], cfg).reshape(t, d)

    return out.reshape(b, s, d).astype(dtype), aux
