"""Multi-head attention: MHA / GQA / MQA, causal / bidirectional / sliding
window, with a fixed-size KV cache for decode.

Layout conventions
  activations: (B, S, D_model)
  q:           (B, S, H, Dh)      grouped as (B, S, Hkv, G, Dh) for GQA
  kv cache:    {"k": (B, T, Hkv, Dh), "v": (B, T, Hkv, Dh)}  (T = max length)

Decode is a single-token step: write (k,v) at position `index`, attend over
the whole cache under a length/window mask — O(T) per token (linear, the
sub-quadratic decode path).  Prefill computes full attention and returns the
populated cache.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.embeddings import apply_rope
from repro.sharding import shard_act

NEG_INF = -1e9

# one warning per (config name, reason): a requested-but-unsupported flash
# path must be loud, not a silent dense fallback
_FLASH_FALLBACK_WARNED: set = set()


def _flash_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """Why this attention call can't run on the flash kernel (None = it can).

    Causal/bidirectional, sliding window, GQA, ragged lengths and padding
    are all kernel-supported; only softcapped logits force the dense path.
    """
    if cfg.logit_softcap is not None:
        return f"logit_softcap={cfg.logit_softcap}"
    return None


def _warn_flash_fallback(cfg: ModelConfig, reason: str) -> None:
    key = (cfg.name, reason)
    if key not in _FLASH_FALLBACK_WARNED:
        _FLASH_FALLBACK_WARNED.add(key)
        warnings.warn(
            f"use_flash_kernel=True on {cfg.name!r} but {reason} is not "
            "supported by the flash kernel; falling back to dense attention "
            "for these calls",
            stacklevel=3,
        )


def attention_defs(cfg: ModelConfig) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": nn.Param((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": nn.Param((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": nn.Param((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": nn.Param((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_qkv_bias:
        defs["bq"] = nn.Param((h, dh), ("heads", "head_dim"), init="zeros",
                              no_weight_decay=True, no_trust_ratio=True)
        defs["bk"] = nn.Param((hkv, dh), ("kv_heads", "head_dim"), init="zeros",
                              no_weight_decay=True, no_trust_ratio=True)
        defs["bv"] = nn.Param((hkv, dh), ("kv_heads", "head_dim"), init="zeros",
                              no_weight_decay=True, no_trust_ratio=True)
    return defs


def _mask_bias(
    q_pos: jnp.ndarray,      # (B, S) int32 — absolute positions of queries
    kv_pos: jnp.ndarray,     # (T,)  int32 — absolute positions of keys
    kv_valid_len: Optional[jnp.ndarray],  # scalar/(B,) — #valid cache slots
    *,
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """(B, 1, S, T) additive mask bias in fp32."""
    q = q_pos[:, :, None]          # (B, S, 1)
    k = kv_pos[None, None, :]      # (1, 1, T)
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if causal:
        ok &= k <= q
    if window is not None:
        ok &= k > q - window
    if kv_valid_len is not None:
        valid = jnp.asarray(kv_valid_len)
        valid = valid[:, None, None] if valid.ndim == 1 else valid
        ok &= k < valid
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[:, None]


def _sdpa(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, T, Hkv, Dh)
    v: jnp.ndarray,  # (B, T, Hkv, Dh)
    bias: jnp.ndarray,  # (B, 1, S, T)
    n_kv_heads: int,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    b, s, h, dh = q.shape
    t = k.shape[1]
    g = h // n_kv_heads
    qg = q.reshape(b, s, n_kv_heads, g, dh)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = scores + bias[:, :, None]  # (B, Hkv, G, S, T) + (B, 1, 1, S, T)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(b, s, h, dh)


def attention(
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    decode: bool = False,
    window: Optional[int] = "cfg",
    valid_len: Optional[jnp.ndarray] = None,  # (B,) per-example valid length
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full attention block (projections + SDPA + output projection).

    Modes:
      train/encoder: cache=None, decode=False
      prefill:       cache=zeros cache, decode=False → returns filled cache
      decode:        cache=filled, decode=True, x is (B, 1, D); positions (B,1)

    ``valid_len`` masks keys at positions >= valid_len[b] in the
    train/prefill path (ragged MLM batches); both the dense and flash
    kernels honor it.
    """
    if window == "cfg":
        window = cfg.sliding_window
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if cfg.use_qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))

    new_cache = None
    if cache is not None and decode:
        # single-token decode: scatter k,v at `index`, attend over full cache.
        # `index` is a scalar (whole batch at one length: static engine) or a
        # (B,) vector (per-slot lengths: continuous-batching KV pool).
        idx = cache["index"]
        if jnp.ndim(idx) == 0:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
            valid = idx + x.shape[1]
        else:
            assert x.shape[1] == 1, "per-slot decode is single-token"
            rows = jnp.arange(x.shape[0])
            ck = cache["k"].at[rows, idx].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, idx].set(v[:, 0].astype(cache["v"].dtype))
            valid = idx + 1
        new_cache = {"k": ck, "v": cv, "index": valid}
        t = ck.shape[1]
        kv_pos = jnp.arange(t, dtype=jnp.int32)
        bias = _mask_bias(positions, kv_pos, valid, causal=True, window=window)
        out = _sdpa(q, shard_act(ck, ("batch", "cache_seq", "kv_heads", None)),
                    shard_act(cv, ("batch", "cache_seq", "kv_heads", None)),
                    bias, cfg.n_kv_heads, cfg.logit_softcap)
    else:
        s = x.shape[1]
        if valid_len is not None:
            # both paths attend at least key 0 for fully-padded examples
            # (their rows carry no loss; this keeps flash ≡ dense exactly)
            valid_len = jnp.maximum(jnp.asarray(valid_len, jnp.int32), 1)
        reason = _flash_unsupported_reason(cfg)
        if cfg.use_flash_kernel and reason is None:
            # flash path: Pallas kernels on TPU, chunked-XLA fallback
            # elsewhere; causal/bidirectional (MLM) and sliding-window,
            # ragged lengths padded+masked inside the wrapper, fwd AND bwd
            from repro.kernels.ops import flash_sdpa

            out = flash_sdpa(
                q, k, v, causal=cfg.causal, kv_valid=valid_len,
                window=window or 0,
            )
        else:
            if cfg.use_flash_kernel:
                _warn_flash_fallback(cfg, reason)
            kv_pos = jnp.arange(s, dtype=jnp.int32)
            bias = _mask_bias(positions, kv_pos, valid_len,
                              causal=cfg.causal, window=window)
            out = _sdpa(q, k, v, bias, cfg.n_kv_heads, cfg.logit_softcap)
        if cache is not None:  # prefill: fill cache[: s]
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv, "index": jnp.asarray(s, jnp.int32)}

    out = shard_act(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return shard_act(y, ("batch", "seq", "embed")), new_cache


def init_kv_cache(
    batch: int, max_len: int, cfg: ModelConfig, dtype=jnp.bfloat16
) -> dict:
    dh = cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def abstract_kv_cache(batch: int, max_len: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    dh = cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, cfg.n_kv_heads, dh), dtype),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
