"""xLSTM layers (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan with exponential gating).

mLSTM train/prefill uses the paper's parallel (attention-like) form with
log-gate stabilization; decode is the O(d^2) recurrent form — the matrix
memory C (B, H, dh, dh), normalizer n and stabilizer m — which is what makes
``long_500k`` decode O(1) in sequence length.

sLSTM is inherently sequential (recurrent R_z/R_i/R_f/R_o block-diagonal per
head); it runs as a ``lax.scan`` over time.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.sharding import shard_act

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    up = int(cfg.xlstm_proj_factor * d)
    dh_up = up // h
    return {
        "up_proj": nn.Param((d, 2 * up), ("embed", "inner")),
        "wq": nn.Param((up, h, dh_up), ("inner", "heads", "head_dim")),
        "wk": nn.Param((up, h, dh_up), ("inner", "heads", "head_dim")),
        "wv": nn.Param((up, h, dh_up), ("inner", "heads", "head_dim")),
        "w_igate": nn.Param((up, h), ("inner", "heads"), init="zeros"),
        "b_igate": nn.Param((h,), ("heads",), init="zeros",
                            no_weight_decay=True, no_trust_ratio=True),
        "w_fgate": nn.Param((up, h), ("inner", "heads"), init="zeros"),
        "b_fgate": nn.Param((h,), ("heads",), init="ones", scale=3.0,
                            no_weight_decay=True, no_trust_ratio=True),
        "out_norm": nn.Param((up,), ("inner",), init="ones",
                             no_weight_decay=True, no_trust_ratio=True),
        "down_proj": nn.Param((up, d), ("inner", "embed")),
    }


def mlstm_parallel(q, k, v, i_pre, f_pre):
    """Parallel mLSTM (paper eq. 25-27).

    q,k,v: (B, H, S, Dh);  i_pre, f_pre: (B, H, S) pre-activations.
    """
    s = q.shape[2]
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))      # (B,H,S)
    F = jnp.cumsum(log_f, axis=-1)                              # sum_{j<=t} log f_j
    # D[t, s] = F[t] - F[s] + i_pre[s]  for s <= t
    D = F[..., :, None] - F[..., None, :] + i_pre.astype(jnp.float32)[..., None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    D = jnp.where(causal, D, NEG_INF)
    m = jnp.max(D, axis=-1, keepdims=True)                      # (B,H,S,1)
    W = jnp.exp(D - m)
    dh = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) / jnp.sqrt(dh)
    C = scores * W
    norm = jnp.maximum(jnp.abs(jnp.sum(C, axis=-1, keepdims=True)), jnp.exp(-m))
    weights = (C / norm).astype(q.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", weights, v)


def mlstm_recurrent_step(state: dict, q, k, v, i_pre, f_pre):
    """One decode step.  q,k,v: (B, H, Dh); gates: (B, H)."""
    c, n, m = state["c"], state["n"], state["m"]  # (B,H,Dh,Dh),(B,H,Dh),(B,H)
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i32 = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, i32)
    f_eff = jnp.exp(log_f + m - m_new)[..., None]
    i_eff = jnp.exp(i32 - m_new)[..., None]
    k32, v32, q32 = (a.astype(jnp.float32) for a in (k, v, q))
    dh = q.shape[-1]
    k32 = k32 / jnp.sqrt(dh)
    c_new = f_eff[..., None] * c + i_eff[..., None] * v32[..., :, None] * k32[..., None, :]
    n_new = f_eff * n + i_eff * k32
    num = jnp.einsum("bhde,bhe->bhd", c_new, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q32)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return {"c": c_new, "n": n_new, "m": m_new}, h


def mlstm_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
    decode: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    dtype = x.dtype
    h_heads = cfg.n_heads
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"].astype(dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard_act(xi, ("batch", "seq", "inner"))
    b, s, up = xi.shape
    dh = up // h_heads

    def heads(w):
        return jnp.einsum("bsu,uhd->bhsd", xi, w.astype(dtype))

    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    i_pre = jnp.einsum("bsu,uh->bhs", xi, p["w_igate"].astype(dtype)) \
        + p["b_igate"].astype(dtype)[None, :, None]
    f_pre = jnp.einsum("bsu,uh->bhs", xi, p["w_fgate"].astype(dtype)) \
        + p["b_fgate"].astype(dtype)[None, :, None]

    new_state = None
    if decode and state is not None:
        new_state, h = mlstm_recurrent_step(
            state, q[:, :, 0], k[:, :, 0], v[:, :, 0], i_pre[:, :, 0], f_pre[:, :, 0]
        )
        h = h[:, :, None]  # (B,H,1,Dh)
    else:
        h = mlstm_parallel(q, k, v, i_pre, f_pre)
        if state is not None:
            # prefill: roll the sequence through the recurrence to build state
            def step(st, inp):
                qq, kk, vv, ii, ff = inp
                st, _ = mlstm_recurrent_step(st, qq, kk, vv, ii, ff)
                return st, None

            xs = (
                q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
                v.transpose(2, 0, 1, 3),
                i_pre.transpose(2, 0, 1), f_pre.transpose(2, 0, 1),
            )
            new_state, _ = jax.lax.scan(step, state, xs)

    h = h.transpose(0, 2, 1, 3).reshape(b, s, up)
    # per-head group norm stand-in: rms over up dim with learned scale
    h32 = h.astype(jnp.float32)
    h = (h32 / jnp.sqrt(jnp.mean(h32**2, -1, keepdims=True) + 1e-6)).astype(dtype)
    h = h * p["out_norm"].astype(dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsu,ud->bsd", h, p["down_proj"].astype(dtype))
    return shard_act(out, ("batch", "seq", "embed")), new_state


def init_mlstm_state(batch: int, cfg: ModelConfig) -> dict:
    h = cfg.n_heads
    up = int(cfg.xlstm_proj_factor * cfg.d_model)
    dh = up // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e9, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = nn.Param((d, h, dh), ("embed", "heads", "head_dim"))
        gates[f"r_{g}"] = nn.Param((h, dh, dh), ("heads", "head_dim", "qk_dim"),
                                   init="fan_in", scale=0.5)
        gates[f"b_{g}"] = nn.Param((h, dh), ("heads", "head_dim"),
                                   init="ones" if g == "f" else "zeros",
                                   no_weight_decay=True, no_trust_ratio=True)
    gates["out_norm"] = nn.Param((d,), ("embed",), init="ones",
                                 no_weight_decay=True, no_trust_ratio=True)
    gates["ff"] = {
        "wi": nn.Param((d, int(cfg.xlstm_proj_factor * d)), ("embed", "ff")),
        "wo": nn.Param((int(cfg.xlstm_proj_factor * d), d), ("ff", "embed")),
    }
    return gates


def slstm_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
    decode: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """sLSTM with exponential gating + stabilizer (paper eq. 13-24)."""
    dtype = x.dtype
    b, s, d = x.shape
    h_heads, dh = cfg.n_heads, d // cfg.n_heads

    pre = {
        g: jnp.einsum("bsd,dhk->bshk", x, p[f"w_{g}"].astype(dtype))
        for g in ("i", "f", "z", "o")
    }

    if state is None:
        state = init_slstm_state(b, cfg)

    def step(st, t_in):
        c, n, m, h_prev = st["c"], st["n"], st["m"], st["h"]

        def gate(g):
            rec = jnp.einsum("bhk,hkj->bhj", h_prev, p[f"r_{g}"].astype(jnp.float32))
            return t_in[g].astype(jnp.float32) + rec + p[f"b_{g}"].astype(jnp.float32)

        i_t, f_t, z_t, o_t = gate("i"), gate("f"), gate("z"), gate("o")
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        i_eff = jnp.exp(i_t - m_new)
        f_eff = jnp.exp(log_f + m - m_new)
        c_new = f_eff * c + i_eff * jnp.tanh(z_t)
        n_new = f_eff * n + i_eff
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
        return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}, h_new

    if decode:
        t_in = {g: pre[g][:, 0] for g in pre}
        new_state, h_out = step(state, t_in)
        hs = h_out[:, None]
    else:
        xs = {g: pre[g].swapaxes(0, 1) for g in pre}  # (S, B, H, Dh)
        new_state, hs = jax.lax.scan(step, state, xs)
        hs = hs.swapaxes(0, 1)  # (B, S, H, Dh)

    y = hs.reshape(b, s, d).astype(dtype)
    y32 = y.astype(jnp.float32)
    y = (y32 / jnp.sqrt(jnp.mean(y32**2, -1, keepdims=True) + 1e-6)).astype(dtype)
    y = y * p["out_norm"].astype(dtype)
    # small gated FF (block-internal)
    ff = jnp.einsum("bsd,df->bsf", y, p["ff"]["wi"].astype(dtype))
    ff = jax.nn.gelu(ff)
    y = jnp.einsum("bsf,fd->bsd", ff, p["ff"]["wo"].astype(dtype))
    return shard_act(y, ("batch", "seq", "embed")), new_state


def init_slstm_state(batch: int, cfg: ModelConfig) -> dict:
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = lambda: jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z(), "n": z(), "m": jnp.full((batch, h, dh), -1e9, jnp.float32),
            "h": z()}


def abstract_mlstm_state(batch: int, cfg: ModelConfig):
    h = cfg.n_heads
    up = int(cfg.xlstm_proj_factor * cfg.d_model)
    dh = up // h
    return {
        "c": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
    }


def abstract_slstm_state(batch: int, cfg: ModelConfig):
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    sds = lambda: jax.ShapeDtypeStruct((batch, h, dh), jnp.float32)
    return {"c": sds(), "n": sds(), "m": sds(), "h": sds()}
