"""Token embeddings, output heads and rotary position embeddings."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro import nn
from repro.sharding import shard_act


def embed_defs(vocab_size: int, d_model: int) -> nn.Param:
    return nn.Param((vocab_size, d_model), ("vocab", "embed"), init="embed", scale=0.02)


def unembed_defs(d_model: int, vocab_size: int) -> nn.Param:
    return nn.Param((d_model, vocab_size), ("embed", "vocab"), init="fan_in")


def embed(table: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    x = table.astype(dtype)[tokens]
    return shard_act(x, ("batch", "seq", "embed"))


def unembed(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("bsd,dv->bsv", x, proj.astype(x.dtype))
    return shard_act(logits, ("batch", "seq", "vocab"))


def tied_unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    return shard_act(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for half the head dim."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    *,
    dim: Optional[int] = None,
) -> jnp.ndarray:
    """Rotate the first `dim` (default: all) features of x.

    x: (B, S, H, D); positions: (B, S) int32.
    """
    d = dim or x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    angles = positions.astype(jnp.float32)[..., None] * inv  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr, rest = x[..., :d], x[..., d:]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = out.astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1) if rest.shape[-1] else out
