"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are projected through low-rank latents; only the
compressed KV latent (kv_lora_rank) and the shared rope key (qk_rope_dim) are
cached for decode — the cache is ~(512+64) floats/token instead of
2*H*Dh = 2*128*192.

Two decode paths:
  * naive (baseline): reconstruct per-head K/V for every cached token each
    step — faithful to the algebra but materializes (B, T, H, Dh).
  * absorbed (``cfg.mla_absorb``, beyond-paper §Perf optimization): fold
    W_uk into the query and W_uv into the output projection so attention runs
    directly in the latent space; the (B, T, H, Dh) blow-up never exists.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.embeddings import apply_rope
from repro.sharding import shard_act

NEG_INF = -1e9


def mla_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": nn.Param((d, qr), ("embed", "q_lora")),
        "q_norm": nn.Param((qr,), ("q_lora",), init="ones",
                           no_weight_decay=True, no_trust_ratio=True),
        "wq_b": nn.Param((qr, h, dn + dr), ("q_lora", "heads", "qk_dim")),
        "wkv_a": nn.Param((d, kr + dr), ("embed", "kv_lora")),
        "kv_norm": nn.Param((kr,), ("kv_lora",), init="ones",
                            no_weight_decay=True, no_trust_ratio=True),
        "wk_b": nn.Param((kr, h, dn), ("kv_lora", "heads", "qk_dim")),
        "wv_b": nn.Param((kr, h, dv), ("kv_lora", "heads", "v_dim")),
        "wo": nn.Param((h, dv, d), ("heads", "v_dim", "embed")),
    }


def _rms(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 / jnp.sqrt(jnp.mean(x32**2, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _latents(p, x, positions, cfg):
    """Shared projections → (q_nope, q_rope, c_kv, k_rope)."""
    dtype = x.dtype
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dtype)), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dtype))
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = _rms(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mask(positions, t, valid_len):
    kv_pos = jnp.arange(t, dtype=jnp.int32)[None, None, :]
    q = positions[:, :, None]
    ok = kv_pos <= q
    if valid_len is not None:
        valid = jnp.asarray(valid_len)
        valid = valid[:, None, None] if valid.ndim == 1 else valid
        ok &= kv_pos < valid
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[:, None]  # (B,1,S,T)


def mla_attention(
    p: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    decode: bool = False,
    valid_len: Optional[jnp.ndarray] = None,  # (B,) per-example valid length
) -> Tuple[jnp.ndarray, Optional[dict]]:
    dtype = x.dtype
    if valid_len is not None:
        # same clamp as attention.py: fully-padded examples keep key 0
        valid_len = jnp.maximum(jnp.asarray(valid_len, jnp.int32), 1)
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / jnp.sqrt(dn + dr).astype(jnp.float32)

    q_nope, q_rope, c_kv, k_rope = _latents(p, x, positions, cfg)

    new_cache = None
    if cache is not None:
        # `index` is a scalar (shared length) or (B,) per-slot lengths — see
        # attention.py; the KV pool drives the per-slot form.
        idx = cache["index"] if decode else jnp.asarray(0, jnp.int32)
        if jnp.ndim(idx) == 0:
            ckv = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0))
            ckr = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, idx, 0))
            valid = idx + x.shape[1]
        else:
            assert x.shape[1] == 1, "per-slot decode is single-token"
            rows = jnp.arange(x.shape[0])
            ckv = cache["c_kv"].at[rows, idx].set(
                c_kv[:, 0].astype(cache["c_kv"].dtype))
            ckr = cache["k_rope"].at[rows, idx].set(
                k_rope[:, 0].astype(cache["k_rope"].dtype))
            valid = idx + 1
        new_cache = {"c_kv": ckv, "k_rope": ckr, "index": valid}
        kv_src, kr_src = ckv.astype(dtype), ckr.astype(dtype)
        if valid_len is not None:  # ragged prefill: example may end < cache
            valid = jnp.minimum(valid, valid_len)
        bias = _mask(positions, ckv.shape[1], valid)
    else:
        kv_src, kr_src = c_kv, k_rope
        bias = _mask(positions, x.shape[1], valid_len)

    kv_src = shard_act(kv_src, ("batch", "cache_seq" if decode else "seq", None))

    if cfg.mla_absorb:
        # ---- absorbed path: attention in latent space -----------------
        # q_lat[b,s,h,r] = q_nope · W_uk[h]   (fold k up-proj into query)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(dtype))
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, kv_src)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_src)
        scores = (s_nope + s_rope).astype(jnp.float32) * scale + bias
        probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, kv_src)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(dtype))
    else:
        # ---- naive path: materialize per-head K/V ---------------------
        k_nope = jnp.einsum("btr,rhk->bthk", kv_src, p["wk_b"].astype(dtype))
        v = jnp.einsum("btr,rhv->bthv", kv_src, p["wv_b"].astype(dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_src[:, :, None, :],
                                      kr_src.shape[:2] + (h, dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32) * scale
        probs = jax.nn.softmax(scores + bias, axis=-1).astype(dtype)
        out = jnp.einsum("bhst,bthv->bshv", probs, v)

    out = shard_act(out, ("batch", "seq", "heads", None))
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dtype))
    return shard_act(y, ("batch", "seq", "embed")), new_cache


def init_mla_cache(batch: int, max_len: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def abstract_mla_cache(batch: int, max_len: int, cfg: ModelConfig, dtype=jnp.bfloat16):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dtype),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }
