"""Normalization layers (param defs + pure applies)."""
from __future__ import annotations

import jax.numpy as jnp

from repro import nn


def norm_defs(d_model: int, norm_type: str) -> dict:
    scale = nn.Param(
        (d_model,), ("embed",), init="ones", no_weight_decay=True, no_trust_ratio=True
    )
    if norm_type == "layernorm":
        bias = nn.Param(
            (d_model,), ("embed",), init="zeros",
            no_weight_decay=True, no_trust_ratio=True,
        )
        return {"scale": scale, "bias": bias}
    return {"scale": scale}


def apply_norm(p: dict, x: jnp.ndarray, norm_type: str, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) / jnp.sqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 / jnp.sqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)
