"""Mamba (S6) selective state-space layer — used by Jamba's hybrid blocks.

Training/prefill uses a parallel associative scan over the diagonal SSM
recurrence; decode keeps O(1) recurrent state (ssm state + conv ring buffer).

TPU adaptation: the CUDA "selective scan" kernel fuses the recurrence in
SRAM; on TPU the same insight maps to ``jax.lax.associative_scan`` (log-depth,
XLA-fused elementwise combines) — optionally *chunked* (``chunk`` argument)
to bound the (B, S, d_inner, d_state) materialization, which is the memory
hillclimb knob recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.sharding import shard_act


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba_dt_rank or math.ceil(cfg.d_model / 16)


def mamba_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    return {
        "in_proj": nn.Param((d, 2 * di), ("embed", "inner")),
        "conv_w": nn.Param((cfg.mamba_d_conv, di), ("conv", "inner"), init="fan_in"),
        "conv_b": nn.Param((di,), ("inner",), init="zeros",
                           no_weight_decay=True, no_trust_ratio=True),
        "x_proj": nn.Param((di, r + 2 * n), ("inner", "state")),
        "dt_proj": nn.Param((r, di), ("state", "inner")),
        "dt_bias": nn.Param((di,), ("inner",), init="uniform_scalar", scale=0.1,
                            no_weight_decay=True, no_trust_ratio=True),
        # A stored as log(-A) for stability; shape (d_inner, n)
        "A_log": nn.Param((di, n), ("inner", "state"), init="uniform_scalar",
                          scale=1.0, no_weight_decay=True),
        "D": nn.Param((di,), ("inner",), init="ones", no_weight_decay=True,
                      no_trust_ratio=True),
        "out_proj": nn.Param((di, d), ("inner", "embed")),
    }


def _ssm_scan(
    a: jnp.ndarray,  # (B, S, Di, N) decay terms exp(dt*A)
    bx: jnp.ndarray,  # (B, S, Di, N) input terms dt*B*x
    h0: Optional[jnp.ndarray] = None,  # (B, Di, N)
    chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + bx_t.  Returns (all h, final h)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if chunk is None or chunk >= a.shape[1]:
        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        return h, h[:, -1]

    # chunked: carry the final state across fixed-size chunks (memory bound
    # by chunk instead of S)
    b, s, di, n = a.shape
    n_chunks = s // chunk
    a_c = a.reshape(b, n_chunks, chunk, di, n).swapaxes(0, 1)
    bx_c = bx.reshape(b, n_chunks, chunk, di, n).swapaxes(0, 1)

    def step(carry, inp):
        ac, bc = inp
        bc = bc.at[:, 0].add(ac[:, 0] * carry)
        _, h = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        return h[:, -1], h

    final, hs = jax.lax.scan(step, jnp.zeros((b, di, n), a.dtype), (a_c, bx_c))
    h = hs.swapaxes(0, 1).reshape(b, s, di, n)
    return h, final


def mamba(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
    decode: bool = False,
    chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, d).  state (decode): {"ssm": (B,Di,N), "conv": (B,dc-1,Di)}."""
    dtype = x.dtype
    di = cfg.mamba_expand * cfg.d_model
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    dc = cfg.mamba_d_conv
    b, s, _ = x.shape

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shard_act(xi, ("batch", "seq", "inner"))

    # depthwise causal conv over time
    if decode and state is not None:
        hist = jnp.concatenate([state["conv"].astype(dtype), xi], axis=1)  # (B, dc-1+s, Di)
        new_conv = hist[:, -(dc - 1):]
        window = hist[:, -dc:]  # (B, dc, Di)
        conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(dtype))[:, None]
    else:
        pad = jnp.zeros((b, dc - 1, di), dtype)
        hist = jnp.concatenate([pad, xi], axis=1)
        idx = jnp.arange(s)[:, None] + jnp.arange(dc)[None]
        windows = hist[:, idx]  # (B, S, dc, Di)
        conv = jnp.einsum("bscd,cd->bsd", windows, p["conv_w"].astype(dtype))
        new_conv = hist[:, -(dc - 1):] if state is not None else None
    conv = jax.nn.silu(conv + p["conv_b"].astype(dtype))

    # data-dependent dt, B, C
    dbc = jnp.einsum("bsd,dr->bsr", conv, p["x_proj"].astype(dtype))
    dt_in, b_in, c_in = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, p["dt_proj"].astype(dtype))
        + p["dt_bias"].astype(dtype)
    ).astype(jnp.float32)  # (B, S, Di)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (Di, N)
    a = jnp.exp(dt[..., None] * A)  # (B, S, Di, N)
    bx = (dt * conv.astype(jnp.float32))[..., None] * b_in.astype(jnp.float32)[:, :, None, :]

    if decode and state is not None:
        h = a[:, 0] * state["ssm"] + bx[:, 0]  # (B, Di, N)
        new_state = {"ssm": h, "conv": new_conv.astype(state["conv"].dtype)}
        y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0].astype(jnp.float32))[:, None]
    else:
        h0 = state["ssm"] if state is not None else None
        hs, h_final = _ssm_scan(a, bx, h0, chunk)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c_in.astype(jnp.float32))
        new_state = (
            {"ssm": h_final, "conv": new_conv.astype(state["conv"].dtype)}
            if state is not None
            else None
        )

    y = (y + conv.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(dtype))
    return shard_act(out, ("batch", "seq", "embed")), new_state


def init_mamba_state(batch: int, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    di = cfg.mamba_expand * cfg.d_model
    return {
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    }


def abstract_mamba_state(batch: int, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    di = cfg.mamba_expand * cfg.d_model
    return {
        "ssm": jax.ShapeDtypeStruct((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.mamba_d_conv - 1, di), dtype),
    }
