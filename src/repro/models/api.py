"""Uniform model API over all architecture families.

``build_model(cfg)`` returns a :class:`Model` exposing:
  defs / init / abstract_params       — parameter trees
  apply(params, batch)                — logits for train/encoder forward
                                        (hidden states with return_hidden=
                                        True — the fused-CE head path)
  prefill(params, batch, cache)       — logits + populated cache
  decode(params, batch, cache)        — one-token step
  make_cache(batch, len, abstract=)   — per-family cache pytree
  input_specs(shape)                  — ShapeDtypeStruct inputs for the
                                        dry-run (tokens / prefix embeddings)
  optimizer metadata                  — wd/trust masks, layer axes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import InputShape, ModelConfig
from repro.models import hybrid, transformer, xlstm_model


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    defs: Any
    forward_fn: Callable
    cache_fn: Callable

    # ---- params ----
    def init(self, rng) -> Any:
        params = nn.init_params(self.defs, rng)
        return nn.cast_tree(params, jnp.dtype(self.cfg.param_dtype))

    def abstract_params(self):
        tree = nn.abstract_params(self.defs)
        dt = jnp.dtype(self.cfg.param_dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dt)
            if jnp.issubdtype(s.dtype, jnp.floating) else s,
            tree,
        )

    # ---- optimizer metadata ----
    def wd_mask(self):
        return nn.weight_decay_mask(self.defs)

    def trust_mask(self):
        return nn.trust_ratio_mask(self.defs)

    def layer_axes(self):
        if self.cfg.lamb_granularity == "leaf":
            return jax.tree.map(lambda _: -1, nn.layer_axis_tree(self.defs))
        return nn.layer_axis_tree(self.defs)

    # ---- compute ----
    def apply(self, params, batch, *, compute_dtype=None, **kw):
        """Training/encoder forward: (logits, aux).

        ``compute_dtype`` (e.g. ``"bfloat16"``) casts floating params before
        the forward so matmuls/activations run in low precision while the
        caller keeps fp32 masters; gradients taken through this cast come
        back in the master dtype (the mixed-precision policy's forward half).

        ``return_hidden=True`` (transformer families only) returns the
        post-final-norm hidden states ``(B, S, D)`` instead of logits — the
        fused-CE head path, where the loss gathers supervised positions and
        projects only those to the vocab (``kernels/fused_ce.py``).
        """
        if compute_dtype is not None:
            params = nn.cast_tree(params, jnp.dtype(compute_dtype))
        logits, _, aux = self.forward_fn(params, batch, self.cfg, **kw)
        return logits, aux

    def prefill(self, params, batch, cache, **kw):
        logits, new_cache, _ = self.forward_fn(
            params, batch, self.cfg, caches=cache, decode=False, **kw
        )
        return logits, new_cache

    def decode(self, params, batch, cache, positions, **kw):
        logits, new_cache, _ = self.forward_fn(
            params, batch, self.cfg, caches=cache, decode=True,
            positions=positions, **kw
        )
        return logits, new_cache

    def make_cache(self, batch: int, max_len: int, *, abstract: bool = False):
        import jax.numpy as _jnp

        return self.cache_fn(
            self.cfg, batch, max_len, abstract=abstract,
            dtype=_jnp.dtype(self.cfg.activation_dtype),
        )

    # ---- dry-run inputs ----
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        return input_specs(self.cfg, shape)

    def param_count(self) -> int:
        return nn.param_count(self.defs)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts + non-MoE)."""
        cfg = self.cfg
        total = nn.param_count(self.defs)
        if cfg.n_experts == 0:
            return total

        # count routed-expert leaves (axes contain "experts"), scale by k/E
        routed = 0
        for leaf in jax.tree.leaves(self.defs, is_leaf=nn.is_param):
            if "experts" in leaf.axes:
                n = 1
                for d in leaf.shape:
                    n *= d
                routed += n
        active_frac = cfg.n_experts_per_tok / cfg.n_experts
        return int(total - routed + routed * active_frac)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "hybrid":
        return Model(cfg, hybrid.hybrid_defs(cfg), hybrid.forward, hybrid.make_cache)
    if cfg.family == "ssm":
        return Model(
            cfg, xlstm_model.xlstm_defs(cfg), xlstm_model.forward,
            xlstm_model.make_cache,
        )
    # dense / moe / vlm / audio share the unified transformer
    return Model(
        cfg, transformer.transformer_defs(cfg), transformer.forward,
        transformer.make_cache,
    )


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    train:   {tokens, labels} (+ modality stubs)
    prefill: {tokens} (+ stubs)
    decode:  {tokens:(B,1)}; the cache is supplied separately.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    d = cfg.d_model
    act = jnp.dtype(cfg.activation_dtype)

    if cfg.frontend == "audio_stub":
        specs = {
            "frame_embeds": jax.ShapeDtypeStruct((b, s, d), act),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    if cfg.frontend == "vision_stub":
        n_img = cfg.n_prefix_tokens
        s_text = s - n_img
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
            "image_embeds": jax.ShapeDtypeStruct((b, n_img, d), act),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return specs

    specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs
