"""Unified transformer: dense / GQA / MQA / sliding-window / MLA / MoE.

Covers 8 of the 10 assigned architectures (all but jamba and xlstm):
smollm, granite-20b, mistral-nemo, command-r, granite-moe, deepseek-v3,
hubert (causal=False), paligemma (prefix embeddings).

Deep stacks are ``lax.scan``'d over stacked parameter leaves; heterogeneous
prefixes (DeepSeek's 3 leading dense layers) are a second, separately
scanned segment.  KV caches are stacked per segment with the same leading
layer axis so they ride through the scan as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    abstract_kv_cache,
    attention,
    attention_defs,
    init_kv_cache,
)
from repro.models.layers.embeddings import (
    embed,
    embed_defs,
    tied_unembed,
    unembed,
    unembed_defs,
)
from repro.models.layers.mla import (
    abstract_mla_cache,
    init_mla_cache,
    mla_attention,
    mla_defs,
)
from repro.models.layers.mlp import mlp, mlp_defs
from repro.models.layers.moe import moe, moe_defs
from repro.models.layers.norms import apply_norm, norm_defs
from repro.sharding import shard_act


def _block_defs(cfg: ModelConfig, *, is_moe: bool) -> dict:
    d = cfg.d_model
    block = {
        "ln1": norm_defs(d, cfg.norm_type),
        "ln2": norm_defs(d, cfg.norm_type),
        "attn": mla_defs(cfg) if cfg.use_mla else attention_defs(cfg),
    }
    if is_moe:
        block["moe"] = moe_defs(cfg)
    else:
        block["mlp"] = mlp_defs(d, cfg.d_ff, cfg.gated_mlp)
    return block


def _n_main(cfg: ModelConfig) -> int:
    return cfg.n_layers - cfg.n_dense_layers


def transformer_defs(cfg: ModelConfig) -> dict:
    main_is_moe = cfg.n_experts > 0
    defs: Dict[str, Any] = {
        "embed": embed_defs(cfg.vocab_size, cfg.d_model),
        "blocks": nn.stack(_block_defs(cfg, is_moe=main_is_moe), _n_main(cfg)),
        "final_norm": norm_defs(cfg.d_model, cfg.norm_type),
    }
    if cfg.n_dense_layers:
        defs["dense_blocks"] = nn.stack(
            _block_defs(cfg, is_moe=False), cfg.n_dense_layers
        )
    if not cfg.tie_embeddings:
        defs["unembed"] = unembed_defs(cfg.d_model, cfg.vocab_size)
    if cfg.frontend == "audio_stub" and cfg.mask_ratio > 0:
        defs["mask_embed"] = nn.Param(
            (cfg.d_model,), ("embed",), init="normal", scale=0.02
        )
    if cfg.use_mtp:
        defs["mtp"] = {
            "proj": nn.Param((2 * cfg.d_model, cfg.d_model), ("inner", "embed")),
            "block": _block_defs(cfg, is_moe=False),
            "norm": norm_defs(cfg.d_model, cfg.norm_type),
        }
    return defs


def _one_block(
    bp: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    cache: Optional[dict],
    decode: bool,
    window,
    valid_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, jnp.ndarray]]:
    h = apply_norm(bp["ln1"], x, cfg.norm_type)
    if cfg.use_mla:
        attn_out, new_cache = mla_attention(
            bp["attn"], h, positions, cfg, cache=cache, decode=decode,
            valid_len=valid_len,
        )
    else:
        attn_out, new_cache = attention(
            bp["attn"], h, positions, cfg, cache=cache, decode=decode,
            window=window, valid_len=valid_len,
        )
    x = x + attn_out
    h = apply_norm(bp["ln2"], x, cfg.norm_type)
    aux: Dict[str, jnp.ndarray] = {}
    if "moe" in bp:
        ff_out, aux = moe(bp["moe"], h, cfg)
    else:
        ff_out = mlp(bp["mlp"], h, cfg)
    return x + ff_out, new_cache, aux


def _scan_segment(
    stacked: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    caches: Optional[dict],
    decode: bool,
    window,
    valid_len: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, jnp.ndarray]]:
    """Scan a homogeneous stack of blocks over the leading layer axis."""

    def body(carry, xs):
        xc = carry
        bp, cache = xs
        y, new_cache, aux = _one_block(
            bp, xc, positions, cfg, cache=cache, decode=decode, window=window,
            valid_len=valid_len,
        )
        return y, (new_cache, aux)

    if cfg.remat == "full":
        body = jax.checkpoint(body)

    if not cfg.scan_layers:
        # unrolled path: identical math, layer-indexed slices (perf knob; also
        # used by the dry-run for while-loop-free cost accounting)
        n = jax.tree.leaves(stacked)[0].shape[0]
        ys = []
        for i in range(n):
            sl = jax.tree.map(lambda a: a[i], stacked)
            ci = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, y = body(x, (sl, ci))
            ys.append(y)
        new_caches = (
            None if caches is None
            else jax.tree.map(lambda *a: jnp.stack(a), *[y[0] for y in ys])
        )
        auxs = {}
        if ys and ys[0][1]:
            auxs = {
                k: jnp.stack([y[1][k] for y in ys]) for k in ys[0][1]
            }
        aux = {k: jnp.mean(v) for k, v in auxs.items()}
        return x, new_caches, aux

    x, (new_caches, auxs) = jax.lax.scan(body, x, (stacked, caches))
    aux = {k: jnp.mean(v) for k, v in auxs.items()}
    return x, new_caches, aux


def _embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, dtype):
    """Token / prefix-embedding entry, per modality frontend."""
    if cfg.frontend == "audio_stub":
        x = batch["frame_embeds"].astype(dtype)
        if cfg.mask_ratio > 0 and "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_embed"].astype(dtype), x)
        return shard_act(x, ("batch", "seq", "embed"))
    x = embed(params["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        # decode steps carry no image prefix (it already lives in the cache)
        prefix = batch["image_embeds"].astype(dtype)
        x = jnp.concatenate([prefix, x], axis=1)
        x = shard_act(x, ("batch", "seq", "embed"))
    return x


def forward(
    params: dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    caches: Optional[dict] = None,
    decode: bool = False,
    positions: Optional[jnp.ndarray] = None,
    window="cfg",
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, jnp.ndarray]]:
    """Returns (logits, new_caches, aux).

    ``return_hidden=True`` skips the unembed projection and returns the
    post-final-norm hidden states ``(B, S, D)`` in the logits slot — the
    fused-CE head path, where the vocab projection runs only over gathered
    supervised positions (see train/loss.py and kernels/fused_ce.py).
    """
    dtype = jnp.dtype(cfg.activation_dtype)
    x = _embed_inputs(params, batch, cfg, dtype)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    caches = caches or {}
    aux: Dict[str, jnp.ndarray] = {}
    new_caches: Dict[str, Any] = {}
    # ragged batches: keys at positions >= valid_len[b] are masked in every
    # train/prefill attention layer (dense bias and flash kernel alike)
    valid_len = None if decode else batch.get("valid_len")

    if "dense_blocks" in params:
        x, nc, a = _scan_segment(
            params["dense_blocks"], x, positions, cfg,
            caches=caches.get("dense"), decode=decode, window=window,
            valid_len=valid_len,
        )
        new_caches["dense"] = nc
        aux.update(a)

    x, nc, a = _scan_segment(
        params["blocks"], x, positions, cfg,
        caches=caches.get("main"), decode=decode, window=window,
        valid_len=valid_len,
    )
    new_caches["main"] = nc
    aux.update({k: (aux[k] + v) / 2 if k in aux else v for k, v in a.items()})

    x = apply_norm(params["final_norm"], x, cfg.norm_type)

    if cfg.use_mtp and not decode:
        aux["mtp_hidden"] = x  # consumed by the MTP head in the loss

    if return_hidden:
        return x, (new_caches if caches else None), aux

    if cfg.tie_embeddings:
        logits = tied_unembed(x, params["embed"])
    else:
        logits = unembed(x, params["unembed"])
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, (new_caches if caches else None), aux


def mtp_logits(
    params: dict, hidden: jnp.ndarray, batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> jnp.ndarray:
    """DeepSeek-V3 single-depth MTP head: predict token t+2 from
    [h_t ; emb(t+1)] through one extra block."""
    dtype = hidden.dtype
    mp = params["mtp"]
    nxt = embed(params["embed"], batch["tokens"], dtype)
    nxt = jnp.roll(nxt, -1, axis=1)
    h = jnp.concatenate([hidden, nxt], axis=-1)
    h = jnp.einsum("bsd,dk->bsk", h, mp["proj"].astype(dtype))
    b, s = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    h, _, _ = _one_block(mp["block"], h, positions, cfg, cache=None,
                         decode=False, window=None)
    h = apply_norm(mp["norm"], h, cfg.norm_type)
    if cfg.tie_embeddings:
        return tied_unembed(h, params["embed"])
    return unembed(h, params["unembed"])


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _stacked_cache(maker, n_layers: int, batch: int, max_len: int, cfg, dtype):
    one = maker(batch, max_len, cfg, dtype)
    if isinstance(jax.tree.leaves(one)[0], jax.ShapeDtypeStruct):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), one
        )
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_layers,) + a.shape).copy(), one)


def make_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, abstract: bool, dtype=jnp.bfloat16
) -> dict:
    kv = (abstract_mla_cache if cfg.use_mla else abstract_kv_cache) if abstract else (
        init_mla_cache if cfg.use_mla else init_kv_cache
    )
    caches = {"main": _stacked_cache(kv, _n_main(cfg), batch, max_len, cfg, dtype)}
    if cfg.n_dense_layers:
        caches["dense"] = _stacked_cache(
            kv, cfg.n_dense_layers, batch, max_len, cfg, dtype
        )
    return caches
