"""xLSTM language model (arXiv:2405.04517): alternating mLSTM / sLSTM blocks.

The scan unit is a (mLSTM, sLSTM) pair when ``slstm_ratio``==2 (the 350M
config), degenerating to all-mLSTM pairs when slstm_ratio==0.
Decode is fully recurrent (matrix memory + scalar memory) — O(1) in sequence
length, which is why this arch runs the ``long_500k`` shape natively.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers.embeddings import embed, embed_defs, tied_unembed
from repro.models.layers.norms import apply_norm, norm_defs
from repro.models.layers.xlstm import (
    abstract_mlstm_state,
    abstract_slstm_state,
    init_mlstm_state,
    init_slstm_state,
    mlstm_block,
    mlstm_defs,
    slstm_block,
    slstm_defs,
)


def _pair_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.slstm_ratio and cfg.slstm_ratio > 0:
        return ("mlstm", "slstm")
    return ("mlstm", "mlstm")


def _pair_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    pair: Dict[str, Any] = {}
    for i, kind in enumerate(_pair_kinds(cfg)):
        pair[f"sub{i}"] = {
            "ln": norm_defs(d, cfg.norm_type),
            "cell": mlstm_defs(cfg) if kind == "mlstm" else slstm_defs(cfg),
        }
    return pair


def xlstm_defs(cfg: ModelConfig) -> dict:
    n_pairs = cfg.n_layers // 2
    return {
        "embed": embed_defs(cfg.vocab_size, cfg.d_model),
        "pairs": nn.stack(_pair_defs(cfg), n_pairs),
        "final_norm": norm_defs(cfg.d_model, cfg.norm_type),
    }


def forward(
    params: dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    caches: Optional[dict] = None,
    decode: bool = False,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, jnp.ndarray]]:
    dtype = jnp.dtype(cfg.activation_dtype)
    x = embed(params["embed"], batch["tokens"], dtype)
    kinds = _pair_kinds(cfg)

    def body(carry, xs):
        xc = carry
        pp, pcache = xs
        new_cache: Dict[str, Any] = {}
        for i, kind in enumerate(kinds):
            sub = pp[f"sub{i}"]
            key = f"sub{i}"
            h = apply_norm(sub["ln"], xc, cfg.norm_type)
            fn = mlstm_block if kind == "mlstm" else slstm_block
            out, st = fn(sub["cell"], h, cfg,
                         state=(pcache or {}).get(key), decode=decode)
            if pcache is not None:
                new_cache[key] = st
            xc = xc + out
        return xc, (new_cache if pcache is not None else None)

    if cfg.remat == "full":
        body = jax.checkpoint(body)

    if not cfg.scan_layers:
        n = jax.tree.leaves(params["pairs"])[0].shape[0]
        ys = []
        for i in range(n):
            pp = jax.tree.map(lambda a: a[i], params["pairs"])
            ci = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, y = body(x, (pp, ci))
            ys.append(y)
        new_caches = (
            None if caches is None
            else jax.tree.map(lambda *a: jnp.stack(a), *ys)
        )
    else:
        x, new_caches = jax.lax.scan(body, x, (params["pairs"], caches))
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = tied_unembed(x, params["embed"])
    return logits, new_caches, {}


def make_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, abstract: bool, dtype=jnp.bfloat16
) -> dict:
    del max_len  # recurrent state is O(1) in sequence length
    n_pairs = cfg.n_layers // 2
    pair: Dict[str, Any] = {}
    for i, kind in enumerate(_pair_kinds(cfg)):
        if kind == "mlstm":
            pair[f"sub{i}"] = (
                abstract_mlstm_state(batch, cfg) if abstract
                else init_mlstm_state(batch, cfg)
            )
        else:
            pair[f"sub{i}"] = (
                abstract_slstm_state(batch, cfg) if abstract
                else init_slstm_state(batch, cfg)
            )
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_pairs,) + s.shape, s.dtype), pair
        )
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_pairs,) + a.shape).copy(), pair
    )
