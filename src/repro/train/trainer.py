"""Trainer: jit'd step loop with metrics, checkpointing and mixed-batch
stages (the paper's two-phase BERT recipe with stage-2 re-warm-up).

Across a stage switch the optimizer *moments* (m, v — ScaleByAdamState /
TraceState) carry over, while schedule counters restart at zero so stage 2
re-warms up — exactly the §4.1 procedure.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.mixed_batch import Stage
from repro.data.pipeline import DataPipeline
from repro.kernels import FusedLambState
from repro.models.api import Model
from repro.optim.base import ScheduleState
from repro.sharding.context import ShardCtx, use_sharding
from repro.train.step import TrainState, make_optimizer, make_train_step


def _batch_examples(batch) -> int:
    """Effective global-batch examples in one step: the leading dim of the
    batch handed to step_fn (= microbatch × accum_steps, since accumulation
    slices this same batch internally)."""
    return int(jax.tree.leaves(batch)[0].shape[0])


def _reset_schedule_counts(opt_state):
    """Zero every schedule counter (stage-2 re-warm-up) keeping moments.

    Resets ``ScheduleState.count`` in unfused chains and
    ``FusedLambState.sched_count`` on the fused path; the moment/bias
    counters carry across stages in both cases (§4.1 procedure).
    """

    def is_node(n):
        return isinstance(n, (ScheduleState, FusedLambState))

    def reset(node):
        if isinstance(node, ScheduleState):
            return ScheduleState(count=jnp.zeros_like(node.count))
        if isinstance(node, FusedLambState):
            return node._replace(sched_count=jnp.zeros_like(node.sched_count))
        return node

    return jax.tree.map(reset, opt_state, is_leaf=is_node)


class Trainer:
    def __init__(
        self,
        model: Model,
        train_cfg: TrainConfig,
        *,
        schedule=None,
        shard_ctx: Optional[ShardCtx] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
    ):
        self.model = model
        self.tc = train_cfg
        self.shard_ctx = shard_ctx
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.log_every = log_every
        self.log = log_fn
        self.history: List[Dict[str, float]] = []
        # Effective examples per optimizer step = microbatch × accum_steps:
        # step_fn consumes the already-assembled global batch, so its leading
        # dim *is* the effective global batch regardless of accumulation.
        # Tracking it here keeps history/benchmarks comparable across
        # accumulation settings.
        self.examples_seen: int = 0
        init_fn, step_fn = make_train_step(model, train_cfg, schedule)
        self._init_fn = init_fn
        self._step_fn = jax.jit(step_fn, donate_argnums=(0,))
        self.state: Optional[TrainState] = None

    # ------------------------------------------------------------------
    def init(self, seed: Optional[int] = None) -> TrainState:
        rng = jax.random.key(self.tc.seed if seed is None else seed)
        with use_sharding(self.shard_ctx):
            self.state = jax.jit(self._init_fn)(rng)
        return self.state

    def fit(self, data, steps: int) -> List[Dict[str, float]]:
        if self.state is None:
            self.init()
        t0 = time.perf_counter()
        with use_sharding(self.shard_ctx):
            for i in range(steps):
                batch = next(data)
                batch = jax.tree.map(jnp.asarray, batch)
                self.examples_seen += _batch_examples(batch)
                self.state, metrics = self._step_fn(self.state, batch)
                if (i + 1) % self.log_every == 0 or i == steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = int(self.state.step)
                    m["examples_seen"] = self.examples_seen
                    m["wall_s"] = time.perf_counter() - t0
                    self.history.append(m)
                    self.log(
                        f"step {m['step']:6d} loss {m.get('loss/total', 0.0):.4f} "
                        f"acc {m.get('accuracy', 0.0):.4f}"
                    )
                if (
                    self.checkpoint_dir
                    and self.checkpoint_every
                    and (i + 1) % self.checkpoint_every == 0
                ):
                    save_checkpoint(
                        self.checkpoint_dir, int(self.state.step), self.state.params
                    )
        return self.history

    # ------------------------------------------------------------------
    def fit_stages(
        self, stages: Sequence[Stage], *, data_seed: int = 0
    ) -> List[Dict[str, float]]:
        """Mixed-batch training: re-jit per stage, carry moments, re-warm-up."""
        if self.state is None:
            self.init()
        for si, stage in enumerate(stages):
            self.log(
                f"== stage {si}: {stage.name} seq={stage.seq_len} "
                f"batch={stage.batch_size} steps={stage.steps} "
                f"lr={stage.learning_rate:.2e} warmup={stage.warmup_steps}"
            )
            opt = make_optimizer(self.model, self.tc, stage.schedule)
            _, step_fn = make_train_step(
                self.model, self.tc, stage.schedule, optimizer=opt
            )
            step_jit = jax.jit(step_fn, donate_argnums=(0,))
            if si > 0:
                # re-warm-up: keep moments, restart schedule counters
                self.state = TrainState(
                    self.state.params,
                    _reset_schedule_counts(self.state.opt_state),
                    self.state.step,
                )
            data = DataPipeline(
                self.model.cfg, stage.batch_size, stage.seq_len, seed=data_seed + si
            )
            with use_sharding(self.shard_ctx):
                for i in range(stage.steps):
                    batch = jax.tree.map(jnp.asarray, next(data))
                    self.examples_seen += _batch_examples(batch)
                    self.state, metrics = step_jit(self.state, batch)
                    if (i + 1) % self.log_every == 0 or i == stage.steps - 1:
                        m = {k: float(v) for k, v in metrics.items()}
                        m["step"] = int(self.state.step)
                        m["examples_seen"] = self.examples_seen
                        m["stage"] = si
                        self.history.append(m)
                        self.log(
                            f"[{stage.name}] step {m['step']:5d} "
                            f"loss {m.get('loss/total', 0.0):.4f}"
                        )
        return self.history
