"""Trainer: jit'd step loop with metrics, checkpointing and mixed-batch
stages (the paper's two-phase BERT recipe with stage-2 re-warm-up).

Across a stage switch the optimizer *moments* (m, v — ScaleByAdamState /
TraceState) carry over, while schedule counters restart at zero so stage 2
re-warms up — exactly the §4.1 procedure.

Sharded training (``mesh=``): the paper's headline run scales LAMB's batch
across a TPU pod, so the step must actually *run* data-parallel.  Given a
mesh, the Trainer computes explicit placements once at construction —
params and every LAMB moment FSDP-sharded via ``sharding.specs_for`` /
``train_state_shardings``, batches split over the data axes — and jits the
step with ``in_shardings``/``out_shardings`` (+ donated state), so XLA
compiles a true SPMD program instead of inferring layouts from one input.
Parameter init runs under partitionable threefry, making initial values
invariant to the mesh shape (the legacy RNG lowering changes bits when its
output is sharded).

Crash safety (``checkpoint_dir`` + ``checkpoint_every``): every save
persists the *full* ``TrainState`` — params, optimizer moments and the step
counter — so a resume continues optimization instead of silently restarting
it.  ``async_checkpoint=True`` routes saves through the double-buffered
:class:`~repro.checkpoint.async_io.AsyncCheckpointer` (the step loop pays
only the device→host snapshot; the disk write overlaps training), and
``resume=True`` restores the latest complete checkpoint at ``fit`` start,
fast-forwarding the data pipeline so the continuation is bit-exact against
a run that was never interrupted (see docs/reliability.md).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    AsyncCheckpointer,
    checkpoint_step,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.mixed_batch import Stage
from repro.data.pipeline import DataPipeline
from repro.kernels import FusedLambState
from repro.models.api import Model
from repro.optim.base import ScheduleState
from repro.sharding.axes import batch_axes, dp_size, specs_for
from repro.sharding.context import ShardCtx, use_sharding
from repro.sharding.placement import batch_sharding, train_state_shardings
from repro.telemetry import (
    EventLog,
    SpanRecorder,
    TrustRecorder,
    run_provenance,
)
from repro.telemetry.trust import PER_LAYER_KEY
from repro.train.step import TrainState, make_optimizer, make_train_step


def _batch_examples(batch) -> int:
    """Effective global-batch examples in one step: the leading dim of the
    batch handed to step_fn (= microbatch × accum_steps, since accumulation
    slices this same batch internally)."""
    return int(jax.tree.leaves(batch)[0].shape[0])


def _reset_schedule_counts(opt_state):
    """Zero every schedule counter (stage-2 re-warm-up) keeping moments.

    Resets ``ScheduleState.count`` in unfused chains and
    ``FusedLambState.sched_count`` on the fused path; the moment/bias
    counters carry across stages in both cases (§4.1 procedure).
    """

    def is_node(n):
        return isinstance(n, (ScheduleState, FusedLambState))

    def reset(node):
        if isinstance(node, ScheduleState):
            return ScheduleState(count=jnp.zeros_like(node.count))
        if isinstance(node, FusedLambState):
            return node._replace(sched_count=jnp.zeros_like(node.sched_count))
        return node

    return jax.tree.map(reset, opt_state, is_leaf=is_node)


class Trainer:
    def __init__(
        self,
        model: Model,
        train_cfg: TrainConfig,
        *,
        schedule=None,
        mesh=None,
        shard_ctx: Optional[ShardCtx] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        async_checkpoint: bool = False,
        resume: bool = False,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        telemetry: Optional[EventLog] = None,
    ):
        self.model = model
        self.tc = train_cfg
        self.mesh = mesh
        self.shard_ctx = shard_ctx
        if mesh is not None and shard_ctx is None:
            self.shard_ctx = ShardCtx(mesh)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        # async: double-buffered background saves (the step loop never
        # blocks on disk); resume: restore the latest persisted full
        # TrainState at fit start and continue from its step
        self.async_checkpoint = async_checkpoint
        self.resume = resume
        self._checkpointer: Optional[AsyncCheckpointer] = None
        self.log_every = log_every
        self.log = log_fn
        # telemetry: a null EventLog unless the caller wires a real sink;
        # everything below guards on .enabled so the default path does no
        # extra device syncs and history stays bit-identical
        self.telemetry = telemetry if telemetry is not None else EventLog()
        self.spans = SpanRecorder(
            log=self.telemetry if self.telemetry.enabled else None
        )
        self.trust_recorder = TrustRecorder(
            log=self.telemetry if self.telemetry.enabled else None
        )
        self._run_started = False
        self.history: List[Dict[str, float]] = []
        # Effective examples per optimizer step = microbatch × accum_steps:
        # step_fn consumes the already-assembled global batch, so its leading
        # dim *is* the effective global batch regardless of accumulation.
        # Tracking it here keeps history/benchmarks comparable across
        # accumulation settings.
        self.examples_seen: int = 0

        self._param_specs = None
        self._batch_sharding = None
        self._state_sharding = None
        self._dp_size = 1
        if mesh is not None:
            self._param_specs = specs_for(model.defs, mesh)
            self._batch_sharding = batch_sharding(mesh)
            self._dp_size = dp_size(mesh)
        init_fn, step_fn = make_train_step(
            model, train_cfg, schedule, param_specs=self._param_specs
        )
        self._init_fn = init_fn
        # abstract state doubles as the restore target: restore_checkpoint
        # shape/dtype-checks every leaf against it (and, on a mesh, places
        # each leaf straight onto its sharding)
        self._abstract_state = jax.eval_shape(
            init_fn, jax.random.key(train_cfg.seed)
        )
        if mesh is not None:
            self._state_sharding = train_state_shardings(
                model.defs, self._abstract_state, mesh
            )
        self._step_fn = self._jit_step(step_fn)
        self.state: Optional[TrainState] = None

    # ------------------------------------------------------------------
    def _jit_step(self, step_fn: Callable) -> Callable:
        """jit a (state, batch) step, with explicit placements on a mesh.

        Donating the state argument lets XLA update params/moments in place
        — without it the sharded step would double the resident optimizer
        memory.  Metric outputs are left unconstrained (scalars replicate).
        """
        if self._state_sharding is None:
            return jax.jit(step_fn, donate_argnums=(0,))
        return jax.jit(
            step_fn,
            in_shardings=(self._state_sharding, self._batch_sharding),
            out_shardings=(self._state_sharding, None),
            donate_argnums=(0,),
        )

    def _place_batch(self, batch):
        """Device-put a host batch (splitting over the data axes on a mesh)."""
        if self._batch_sharding is None:
            return jax.tree.map(jnp.asarray, batch)
        n = _batch_examples(batch)
        if n % self._dp_size:
            raise ValueError(
                f"global batch {n} is not divisible by the mesh's "
                f"data-parallel size {self._dp_size} "
                f"(axes {batch_axes(self.mesh)}); examples would be dropped"
            )
        def place(x):
            # already committed to the step's layout (DataPipeline(mesh=)):
            # re-placing would gather the global batch to host every step
            if getattr(x, "sharding", None) == self._batch_sharding:
                return x
            return jax.device_put(np.asarray(x), self._batch_sharding)

        return jax.tree.map(place, batch)

    # ------------------------------------------------------------------
    def init(self, seed: Optional[int] = None) -> TrainState:
        rng = jax.random.key(self.tc.seed if seed is None else seed)
        # Partitionable threefry makes init values independent of the mesh
        # shape (and of sharded vs single-device execution) — required for
        # the sharded ≡ single-device equivalence this Trainer guarantees.
        with use_sharding(self.shard_ctx), jax.threefry_partitionable(True):
            if self._state_sharding is None:
                self.state = jax.jit(self._init_fn)(rng)
            else:
                self.state = jax.jit(
                    self._init_fn, out_shardings=self._state_sharding
                )(rng)
        return self.state

    # ------------------------------------------------------------------
    def _emit_run_start(self) -> None:
        if self._run_started or not self.telemetry.enabled:
            return
        self._run_started = True
        self.telemetry.emit(
            "run_start",
            provenance=run_provenance(
                mesh=self.mesh, configs=(self.model.cfg, self.tc)
            ),
            arch=self.model.cfg.name,
            optimizer=self.tc.optimizer,
        )

    def _host_metrics(self, metrics):
        """Fetch the whole metrics pytree with ONE ``device_get`` (not one
        blocking sync per metric leaf) and convert on host; pops the
        per-layer telemetry records out of the scalar history."""
        host = jax.device_get(dict(metrics))
        per_layer = host.pop(PER_LAYER_KEY, None)
        return {k: float(v) for k, v in host.items()}, per_layer

    def _log_step(self, m: Dict[str, float], per_layer, step_s: float,
                  n_steps: int) -> None:
        """Emit the log-step telemetry: step event + trust records."""
        scalars = {k: v for k, v in m.items()
                   if k not in ("step", "examples_seen", "wall_s", "stage")}
        ev = dict(step=m["step"], examples_seen=m["examples_seen"],
                  wall_s=m["wall_s"], metrics=scalars)
        if "stage" in m:
            ev["stage"] = m["stage"]
        if n_steps:
            ev["step_time_s"] = step_s / n_steps
        self.telemetry.emit("step", **ev)
        if per_layer is not None:
            self.trust_recorder.record(m["step"], per_layer)

    # ------------------------------------------------------------------
    # checkpointing + resume
    # ------------------------------------------------------------------
    @property
    def checkpointer(self) -> AsyncCheckpointer:
        """Lazy double-buffered async writer (created on first async save)."""
        if self._checkpointer is None:
            self._checkpointer = AsyncCheckpointer(
                self.checkpoint_dir, telemetry=self.telemetry
            )
        return self._checkpointer

    def _save_checkpoint(self) -> None:
        """Persist the FULL TrainState — params, optimizer moments and the
        step counter.  A params-only save silently restarts optimization on
        resume: LAMB's m/v moments and the schedule position are state."""
        step = int(self.state.step)
        if self.async_checkpoint:
            self.checkpointer.save(step, self.state)
            return
        t0 = time.perf_counter()
        path = save_checkpoint(self.checkpoint_dir, step, self.state)
        self.telemetry.emit(
            "checkpoint", step=step, path=path, mode="sync",
            write_s=time.perf_counter() - t0,
        )

    def _drain_checkpoints(self) -> None:
        """Block until the in-flight async write (if any) is durable, so a
        returned ``fit`` implies every scheduled checkpoint is on disk."""
        if self._checkpointer is not None:
            self._checkpointer.wait()

    def restore(self, path: Optional[str] = None) -> Optional[int]:
        """Restore the full TrainState from ``path`` (default: the latest
        complete checkpoint in ``checkpoint_dir``).  Returns the restored
        step, or None when there is nothing to restore.  On a mesh each
        leaf is placed straight onto its sharding — a checkpoint written
        on one mesh shape restores onto another."""
        if path is None:
            path = (latest_checkpoint(self.checkpoint_dir)
                    if self.checkpoint_dir else None)
        if path is None:
            return None
        restored = restore_checkpoint(
            path, self._abstract_state, shardings=self._state_sharding
        )
        if self._state_sharding is None:
            restored = jax.tree.map(jnp.asarray, restored)
        self.state = restored
        step = checkpoint_step(path)
        self.telemetry.emit("resume", step=step, path=path)
        self.log(f"resumed step {step} from {path}")
        return step

    def _maybe_resume(self, data, steps: int) -> int:
        """With ``resume=True``, restore the latest checkpoint and return
        the step to continue from (0 when none exists).  The deterministic
        data iterator is fast-forwarded past the batches the original run
        already consumed, so the resumed run sees exactly the sequence an
        uninterrupted run would — the bit-exact-continuation contract the
        preemption harness asserts."""
        if not self.resume:
            return 0
        step = self.restore()
        if step is None:
            return 0
        start = min(step, steps)
        for _ in range(start):
            self.examples_seen += _batch_examples(next(data))
        return start

    # ------------------------------------------------------------------
    def fit(self, data, steps: int) -> List[Dict[str, float]]:
        start = self._maybe_resume(data, steps)
        if self.state is None:
            self.init()
        self._emit_run_start()
        telem = self.telemetry.enabled
        t0 = time.perf_counter()
        since_log = 0
        with use_sharding(self.shard_ctx):
            for i in range(start, steps):
                if telem and since_log == 0:
                    # span boundary: drain prior work so the interval times
                    # only its own steps (async dispatch would otherwise
                    # attribute queued work to the wrong interval)
                    self.spans.start("step", sync=self.state)
                batch = self._place_batch(next(data))
                self.examples_seen += _batch_examples(batch)
                self.state, metrics = self._step_fn(self.state, batch)
                since_log += 1
                if (i + 1) % self.log_every == 0 or i == steps - 1:
                    m, per_layer = self._host_metrics(metrics)
                    step_s = (
                        self.spans.stop("step", sync=self.state,
                                        count=since_log)
                        if telem else 0.0
                    )
                    m["step"] = int(self.state.step)
                    m["examples_seen"] = self.examples_seen
                    m["wall_s"] = time.perf_counter() - t0
                    self.history.append(m)
                    self.log(
                        f"step {m['step']:6d} loss {m.get('loss/total', 0.0):.4f} "
                        f"acc {m.get('accuracy', 0.0):.4f}"
                    )
                    if telem:
                        self._log_step(m, per_layer, step_s, since_log)
                    since_log = 0
                if (
                    self.checkpoint_dir
                    and self.checkpoint_every
                    and (i + 1) % self.checkpoint_every == 0
                ):
                    self._save_checkpoint()
        self._drain_checkpoints()
        return self.history

    # ------------------------------------------------------------------
    def fit_stages(
        self, stages: Sequence[Stage], *, data_seed: int = 0
    ) -> List[Dict[str, float]]:
        """Mixed-batch training: re-jit per stage, carry moments, re-warm-up."""
        if self.state is None:
            self.init()
        self._emit_run_start()
        telem = self.telemetry.enabled
        # one wall clock across all stages, so fit_stages history rows carry
        # the same ``wall_s`` field as fit's and stay comparable
        t0 = time.perf_counter()
        for si, stage in enumerate(stages):
            self.log(
                f"== stage {si}: {stage.name} seq={stage.seq_len} "
                f"batch={stage.batch_size} steps={stage.steps} "
                f"lr={stage.learning_rate:.2e} warmup={stage.warmup_steps}"
            )
            self.telemetry.emit(
                "stage_start", stage=si, name=stage.name,
                seq_len=stage.seq_len, batch_size=stage.batch_size,
                steps=stage.steps, learning_rate=stage.learning_rate,
                warmup_steps=stage.warmup_steps,
            )
            opt = make_optimizer(
                self.model, self.tc, stage.schedule,
                param_specs=self._param_specs,
            )
            _, step_fn = make_train_step(
                self.model, self.tc, stage.schedule, optimizer=opt,
                param_specs=self._param_specs,
            )
            step_jit = self._jit_step(step_fn)
            if si > 0:
                # re-warm-up: keep moments, restart schedule counters
                self.state = TrainState(
                    self.state.params,
                    _reset_schedule_counts(self.state.opt_state),
                    self.state.step,
                )
            data = DataPipeline(
                self.model.cfg, stage.batch_size, stage.seq_len, seed=data_seed + si
            )
            since_log = 0
            with use_sharding(self.shard_ctx):
                for i in range(stage.steps):
                    if telem and since_log == 0:
                        self.spans.start("step", sync=self.state)
                    batch = self._place_batch(next(data))
                    self.examples_seen += _batch_examples(batch)
                    self.state, metrics = step_jit(self.state, batch)
                    since_log += 1
                    if (i + 1) % self.log_every == 0 or i == stage.steps - 1:
                        m, per_layer = self._host_metrics(metrics)
                        step_s = (
                            self.spans.stop("step", sync=self.state,
                                            count=since_log)
                            if telem else 0.0
                        )
                        m["step"] = int(self.state.step)
                        m["examples_seen"] = self.examples_seen
                        m["wall_s"] = time.perf_counter() - t0
                        m["stage"] = si
                        self.history.append(m)
                        self.log(
                            f"[{stage.name}] step {m['step']:5d} "
                            f"loss {m.get('loss/total', 0.0):.4f}"
                        )
                        if telem:
                            self._log_step(m, per_layer, step_s, since_log)
                        since_log = 0
        return self.history
