"""Trainer: jit'd step loop with metrics, checkpointing and mixed-batch
stages (the paper's two-phase BERT recipe with stage-2 re-warm-up).

Across a stage switch the optimizer *moments* (m, v — ScaleByAdamState /
TraceState) carry over, while schedule counters restart at zero so stage 2
re-warms up — exactly the §4.1 procedure.

Sharded training (``mesh=``): the paper's headline run scales LAMB's batch
across a TPU pod, so the step must actually *run* data-parallel.  Given a
mesh, the Trainer computes explicit placements once at construction —
params and every LAMB moment FSDP-sharded via ``sharding.specs_for`` /
``train_state_shardings``, batches split over the data axes — and jits the
step with ``in_shardings``/``out_shardings`` (+ donated state), so XLA
compiles a true SPMD program instead of inferring layouts from one input.
Parameter init runs under partitionable threefry, making initial values
invariant to the mesh shape (the legacy RNG lowering changes bits when its
output is sharded).

Crash safety (``checkpoint_dir`` + ``checkpoint_every``): every save
persists the *full* ``TrainState`` — params, optimizer moments and the step
counter — so a resume continues optimization instead of silently restarting
it.  ``async_checkpoint=True`` routes saves through the double-buffered
:class:`~repro.checkpoint.async_io.AsyncCheckpointer` (the step loop pays
only the device→host snapshot; the disk write overlaps training), and
``resume=True`` restores the latest complete checkpoint at ``fit`` start,
fast-forwarding the data pipeline so the continuation is bit-exact against
a run that was never interrupted (see docs/reliability.md).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    AsyncCheckpointer,
    checkpoint_step,
    discard_checkpoints_after,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.mixed_batch import Stage
from repro.data.pipeline import DataPipeline
from repro.kernels import FusedLambState
from repro.models.api import Model
from repro.optim.base import ScheduleState
from repro.sharding.axes import batch_axes, dp_size, specs_for
from repro.sharding.context import ShardCtx, use_sharding
from repro.sharding.placement import batch_sharding, train_state_shardings
from repro.telemetry import (
    EventLog,
    SpanRecorder,
    TrustRecorder,
    run_provenance,
)
from repro.telemetry.trust import PER_LAYER_KEY
from repro.train.preempt import PreemptionHandler
from repro.train.step import (
    LOSS_KEY,
    TrainState,
    make_optimizer,
    make_train_step,
)
from repro.train.supervisor import (
    DivergenceError,
    SupervisorConfig,
    TrainingSupervisor,
)


def _batch_examples(batch) -> int:
    """Effective global-batch examples in one step: the leading dim of the
    batch handed to step_fn (= microbatch × accum_steps, since accumulation
    slices this same batch internally)."""
    return int(jax.tree.leaves(batch)[0].shape[0])


def _reset_schedule_counts(opt_state):
    """Zero every schedule counter (stage-2 re-warm-up) keeping moments.

    Resets ``ScheduleState.count`` in unfused chains and
    ``FusedLambState.sched_count`` on the fused path; the moment/bias
    counters carry across stages in both cases (§4.1 procedure).
    """

    def is_node(n):
        return isinstance(n, (ScheduleState, FusedLambState))

    def reset(node):
        if isinstance(node, ScheduleState):
            return ScheduleState(count=jnp.zeros_like(node.count))
        if isinstance(node, FusedLambState):
            return node._replace(sched_count=jnp.zeros_like(node.sched_count))
        return node

    return jax.tree.map(reset, opt_state, is_leaf=is_node)


class Trainer:
    def __init__(
        self,
        model: Model,
        train_cfg: TrainConfig,
        *,
        schedule=None,
        mesh=None,
        shard_ctx: Optional[ShardCtx] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        async_checkpoint: bool = False,
        resume: bool = False,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        telemetry: Optional[EventLog] = None,
        supervisor: Optional[SupervisorConfig] = None,
        preempt_grace: Optional[float] = None,
    ):
        self.model = model
        self.tc = train_cfg
        self.mesh = mesh
        self.shard_ctx = shard_ctx
        if mesh is not None and shard_ctx is None:
            self.shard_ctx = ShardCtx(mesh)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        # async: double-buffered background saves (the step loop never
        # blocks on disk); resume: restore the latest persisted full
        # TrainState at fit start and continue from its step
        self.async_checkpoint = async_checkpoint
        self.resume = resume
        self._checkpointer: Optional[AsyncCheckpointer] = None
        # loss-spike watchdog: a fresh TrainingSupervisor is built per fit
        # from this config (rollback counts must not leak across fits)
        self.supervisor_cfg = supervisor
        # preemption: not None installs a SIGTERM/SIGINT handler around the
        # fit loop; the value bounds (seconds) the final-save drain wait
        self.preempt_grace = preempt_grace
        self._last_saved_step: Optional[int] = None
        self._skipped_seen = 0
        self._status = "ok"
        self.log_every = log_every
        self.log = log_fn
        # telemetry: a null EventLog unless the caller wires a real sink;
        # everything below guards on .enabled so the default path does no
        # extra device syncs and history stays bit-identical
        self.telemetry = telemetry if telemetry is not None else EventLog()
        self.spans = SpanRecorder(
            log=self.telemetry if self.telemetry.enabled else None
        )
        self.trust_recorder = TrustRecorder(
            log=self.telemetry if self.telemetry.enabled else None
        )
        self._run_started = False
        self.history: List[Dict[str, float]] = []
        # Effective examples per optimizer step = microbatch × accum_steps:
        # step_fn consumes the already-assembled global batch, so its leading
        # dim *is* the effective global batch regardless of accumulation.
        # Tracking it here keeps history/benchmarks comparable across
        # accumulation settings.
        self.examples_seen: int = 0

        self._param_specs = None
        self._batch_sharding = None
        self._state_sharding = None
        self._dp_size = 1
        if mesh is not None:
            self._param_specs = specs_for(model.defs, mesh)
            self._batch_sharding = batch_sharding(mesh)
            self._dp_size = dp_size(mesh)
        init_fn, step_fn = make_train_step(
            model, train_cfg, schedule, param_specs=self._param_specs
        )
        self._init_fn = init_fn
        # abstract state doubles as the restore target: restore_checkpoint
        # shape/dtype-checks every leaf against it (and, on a mesh, places
        # each leaf straight onto its sharding)
        self._abstract_state = jax.eval_shape(
            init_fn, jax.random.key(train_cfg.seed)
        )
        if mesh is not None:
            self._state_sharding = train_state_shardings(
                model.defs, self._abstract_state, mesh
            )
        self._step_fn = self._jit_step(step_fn)
        self.state: Optional[TrainState] = None

    # ------------------------------------------------------------------
    def _jit_step(self, step_fn: Callable) -> Callable:
        """jit a (state, batch) step, with explicit placements on a mesh.

        Donating the state argument lets XLA update params/moments in place
        — without it the sharded step would double the resident optimizer
        memory.  Metric outputs are left unconstrained (scalars replicate).
        """
        if self._state_sharding is None:
            return jax.jit(step_fn, donate_argnums=(0,))
        return jax.jit(
            step_fn,
            in_shardings=(self._state_sharding, self._batch_sharding),
            out_shardings=(self._state_sharding, None),
            donate_argnums=(0,),
        )

    def _place_batch(self, batch):
        """Device-put a host batch (splitting over the data axes on a mesh)."""
        if self._batch_sharding is None:
            return jax.tree.map(jnp.asarray, batch)
        n = _batch_examples(batch)
        if n % self._dp_size:
            raise ValueError(
                f"global batch {n} is not divisible by the mesh's "
                f"data-parallel size {self._dp_size} "
                f"(axes {batch_axes(self.mesh)}); examples would be dropped"
            )
        def place(x):
            # already committed to the step's layout (DataPipeline(mesh=)):
            # re-placing would gather the global batch to host every step
            if getattr(x, "sharding", None) == self._batch_sharding:
                return x
            return jax.device_put(np.asarray(x), self._batch_sharding)

        return jax.tree.map(place, batch)

    # ------------------------------------------------------------------
    def init(self, seed: Optional[int] = None) -> TrainState:
        rng = jax.random.key(self.tc.seed if seed is None else seed)
        # Partitionable threefry makes init values independent of the mesh
        # shape (and of sharded vs single-device execution) — required for
        # the sharded ≡ single-device equivalence this Trainer guarantees.
        with use_sharding(self.shard_ctx), jax.threefry_partitionable(True):
            if self._state_sharding is None:
                self.state = jax.jit(self._init_fn)(rng)
            else:
                self.state = jax.jit(
                    self._init_fn, out_shardings=self._state_sharding
                )(rng)
        return self.state

    # ------------------------------------------------------------------
    def _emit_run_start(self) -> None:
        if self._run_started or not self.telemetry.enabled:
            return
        self._run_started = True
        self.telemetry.emit(
            "run_start",
            provenance=run_provenance(
                mesh=self.mesh, configs=(self.model.cfg, self.tc)
            ),
            arch=self.model.cfg.name,
            optimizer=self.tc.optimizer,
        )

    def _host_metrics(self, metrics):
        """Fetch the whole metrics pytree with ONE ``device_get`` (not one
        blocking sync per metric leaf) and convert on host; pops the
        per-layer telemetry records out of the scalar history."""
        host = jax.device_get(dict(metrics))
        per_layer = host.pop(PER_LAYER_KEY, None)
        return {k: float(v) for k, v in host.items()}, per_layer

    def _log_step(self, m: Dict[str, float], per_layer, step_s: float,
                  n_steps: int) -> None:
        """Emit the log-step telemetry: step event + trust records."""
        scalars = {k: v for k, v in m.items()
                   if k not in ("step", "examples_seen", "wall_s", "stage")}
        ev = dict(step=m["step"], examples_seen=m["examples_seen"],
                  wall_s=m["wall_s"], metrics=scalars)
        if "stage" in m:
            ev["stage"] = m["stage"]
        if n_steps:
            ev["step_time_s"] = step_s / n_steps
        self.telemetry.emit("step", **ev)
        if per_layer is not None:
            self.trust_recorder.record(m["step"], per_layer)

    # ------------------------------------------------------------------
    # checkpointing + resume
    # ------------------------------------------------------------------
    @property
    def checkpointer(self) -> AsyncCheckpointer:
        """Lazy double-buffered async writer (created on first async save)."""
        if self._checkpointer is None:
            self._checkpointer = AsyncCheckpointer(
                self.checkpoint_dir, telemetry=self.telemetry
            )
        return self._checkpointer

    def _save_checkpoint(self) -> None:
        """Persist the FULL TrainState — params, optimizer moments and the
        step counter.  A params-only save silently restarts optimization on
        resume: LAMB's m/v moments and the schedule position are state.

        Same-step re-saves are dropped: with the non-finite guard, skipped
        steps can make two cadence points (or cadence + preemption) land on
        one ``state.step`` — the state is identical, the write is not free.
        """
        step = int(self.state.step)
        if step == self._last_saved_step:
            return
        self._last_saved_step = step
        if self.async_checkpoint:
            self.checkpointer.save(step, self.state)
            return
        t0 = time.perf_counter()
        path = save_checkpoint(self.checkpoint_dir, step, self.state)
        self.telemetry.emit(
            "checkpoint", step=step, path=path, mode="sync",
            write_s=time.perf_counter() - t0,
        )

    def _drain_checkpoints(self, timeout: Optional[float] = None) -> None:
        """Block until the in-flight async write (if any) is durable, so a
        returned ``fit`` implies every scheduled checkpoint is on disk.
        ``timeout`` bounds the wait (the preemption grace window)."""
        if self._checkpointer is not None:
            self._checkpointer.wait(timeout)

    def restore(self, path: Optional[str] = None) -> Optional[int]:
        """Restore the full TrainState from ``path`` (default: the latest
        complete checkpoint in ``checkpoint_dir``).  Returns the restored
        step, or None when there is nothing to restore.  On a mesh each
        leaf is placed straight onto its sharding — a checkpoint written
        on one mesh shape restores onto another."""
        if path is None:
            path = (latest_checkpoint(self.checkpoint_dir)
                    if self.checkpoint_dir else None)
        if path is None:
            return None
        restored = restore_checkpoint(
            path, self._abstract_state, shardings=self._state_sharding
        )
        if self._state_sharding is None:
            restored = jax.tree.map(jnp.asarray, restored)
        self.state = restored
        step = checkpoint_step(path)
        self.telemetry.emit("resume", step=step, path=path)
        self.log(f"resumed step {step} from {path}")
        return step

    def _maybe_resume(self, data, steps: int) -> int:
        """With ``resume=True``, restore the latest checkpoint and return
        the batch ordinal to continue from (0 when none exists).  The
        deterministic data iterator is fast-forwarded past the batches the
        original run already consumed — ``step + skipped``, since a
        guard-skipped step consumed a batch without advancing ``step`` —
        so the resumed run sees exactly the sequence an uninterrupted run
        would: the bit-exact-continuation contract the preemption harness
        asserts."""
        if not self.resume:
            return 0
        step = self.restore()
        if step is None:
            return 0
        self._last_saved_step = step
        start = min(step + int(self.state.skipped), steps)
        for _ in range(start):
            self.examples_seen += _batch_examples(next(data))
        return start

    # ------------------------------------------------------------------
    def fit(self, data, steps: int, *,
            data_factory: Optional[Callable[[], Any]] = None
            ) -> List[Dict[str, float]]:
        """Run the step loop to ``steps`` batches.

        ``data_factory`` (a zero-arg callable rebuilding the deterministic
        iterator ``data`` came from) enables supervisor rollback: on a trip
        the Trainer restores the last validated checkpoint, rebuilds the
        stream, and fast-forwards *past* the suspect batch window.  A
        ``run_end`` event with an explicit status (``ok`` / ``failed`` /
        ``preempted`` / ``diverged``) is emitted from a ``finally`` so
        crashed runs still close their event log.
        """
        if data is None and data_factory is not None:
            data = data_factory()
        start = self._maybe_resume(data, steps)
        if self.state is None:
            self.init()
        self._emit_run_start()
        supervisor = (TrainingSupervisor(self.supervisor_cfg)
                      if self.supervisor_cfg is not None else None)
        self._status = "ok"
        try:
            with PreemptionHandler(
                enabled=self.preempt_grace is not None
            ) as preempt:
                self._fit_loop(data, steps, start, supervisor, preempt,
                               data_factory)
            self._drain_checkpoints()
        except BaseException as e:
            self._status = ("diverged" if isinstance(e, DivergenceError)
                            else "failed")
            raise
        finally:
            self._emit_run_end(supervisor)
        return self.history

    def _fit_loop(self, data, steps: int, start: int,
                  supervisor: Optional[TrainingSupervisor],
                  preempt: PreemptionHandler,
                  data_factory: Optional[Callable[[], Any]]) -> None:
        telem = self.telemetry.enabled
        guard_on = self.tc.skip_nonfinite
        t0 = time.perf_counter()
        since_log = 0
        self._skipped_seen = int(self.state.skipped) if guard_on else 0
        # i is the batch ordinal (stream position), not state.step: a
        # guard-skipped step consumes a batch without advancing step, and
        # the two counters must not be conflated in the loop bookkeeping
        i = start
        with use_sharding(self.shard_ctx):
            while i < steps:
                if telem and since_log == 0:
                    # span boundary: drain prior work so the interval times
                    # only its own steps (async dispatch would otherwise
                    # attribute queued work to the wrong interval)
                    self.spans.start("step", sync=self.state)
                batch = self._place_batch(next(data))
                self.examples_seen += _batch_examples(batch)
                self.state, metrics = self._step_fn(self.state, batch)
                since_log += 1
                if supervisor is not None:
                    # the watchdog's cost: one blocking host fetch per step
                    loss_d, step_d, skip_d = jax.device_get(
                        (metrics.get(LOSS_KEY), self.state.step,
                         self.state.skipped))
                    loss = float("nan") if loss_d is None else float(loss_d)
                    step_now, skipped_now = int(step_d), int(skip_d)
                    delta = skipped_now - self._skipped_seen
                    self._skipped_seen = skipped_now
                    if delta > 0:
                        self.telemetry.emit(
                            "nonfinite_step", step=step_now, count=delta,
                            total=skipped_now,
                            consecutive=supervisor.consecutive_skips + 1,
                        )
                        self.log(f"non-finite step skipped at batch {i} "
                                 f"(total skipped {skipped_now})")
                    reason = supervisor.observe(step_now, loss, skipped_now)
                    if reason is not None:
                        i, data = self._rollback(
                            reason, supervisor, i, steps, step_now,
                            data_factory,
                        )
                        since_log = 0
                        continue
                if (i + 1) % self.log_every == 0 or i == steps - 1:
                    m, per_layer = self._host_metrics(metrics)
                    step_s = (
                        self.spans.stop("step", sync=self.state,
                                        count=since_log)
                        if telem else 0.0
                    )
                    m["step"] = int(self.state.step)
                    m["examples_seen"] = self.examples_seen
                    m["wall_s"] = time.perf_counter() - t0
                    if guard_on:
                        skipped_now = int(self.state.skipped)
                        m["skipped_total"] = skipped_now
                        if supervisor is None:
                            if skipped_now > self._skipped_seen:
                                self.telemetry.emit(
                                    "nonfinite_step", step=m["step"],
                                    count=skipped_now - self._skipped_seen,
                                    total=skipped_now,
                                )
                            self._skipped_seen = skipped_now
                    self.history.append(m)
                    self.log(
                        f"step {m['step']:6d} loss {m.get('loss/total', 0.0):.4f} "
                        f"acc {m.get('accuracy', 0.0):.4f}"
                    )
                    if telem:
                        self._log_step(m, per_layer, step_s, since_log)
                    since_log = 0
                if (
                    self.checkpoint_dir
                    and self.checkpoint_every
                    and (i + 1) % self.checkpoint_every == 0
                ):
                    self._save_checkpoint()
                i += 1
                if preempt.triggered:
                    self._handle_preempt(preempt)
                    self._status = "preempted"
                    break

    # ------------------------------------------------------------------
    def _rollback(self, reason: str, supervisor: TrainingSupervisor,
                  i: int, steps: int, trip_step: int,
                  data_factory: Optional[Callable[[], Any]]):
        """Restore the last validated checkpoint and fast-forward the data
        stream past the suspect window.  Returns ``(next_i, new_data)``.

        Resuming the stream at ``i + 1`` — not at the restored step — is
        the re-poisoning guard: the batches between the restored checkpoint
        and the trip (the window that contained the poison) are consumed
        untrained, so even a deterministic persistent fault at one ordinal
        can never hit the rolled-back run twice.
        """
        diag = supervisor.diagnostics(reason)
        self.log(f"supervisor trip: {reason} at batch {i} "
                 f"(step {trip_step}, last_good {supervisor.last_good})")
        supervisor.note_rollback(reason)  # raises DivergenceError past budget
        if not self.checkpoint_dir or data_factory is None:
            raise DivergenceError(
                f"diverged ({reason}): rollback needs checkpoint_dir and a "
                "data_factory", diag,
            )
        self._drain_checkpoints()
        bound = supervisor.last_good
        path = (latest_checkpoint(self.checkpoint_dir, max_step=bound)
                if bound >= 0 else None)
        if path is None:
            raise DivergenceError(
                f"diverged ({reason}) before any validated checkpoint "
                f"(last_good step {bound})", diag,
            )
        restored_step = self.restore(path)
        removed = discard_checkpoints_after(self.checkpoint_dir,
                                            restored_step)
        self._last_saved_step = restored_step
        restored_skipped = int(self.state.skipped)
        restored_i = restored_step + restored_skipped
        resume_i = i + 1
        data = data_factory()
        for _ in range(resume_i):
            next(data)  # already consumed pre-trip; examples_seen unchanged
        self.telemetry.emit(
            "rollback", step=restored_step, from_step=trip_step,
            reason=reason, batches_dropped=resume_i - restored_i,
            rollbacks=supervisor.rollbacks, discarded=len(removed),
        )
        supervisor.after_rollback(restored_skipped)
        self._skipped_seen = restored_skipped
        self.log(f"rollback {supervisor.rollbacks}: restored step "
                 f"{restored_step}, dropped batches "
                 f"[{restored_i}, {resume_i}), resuming at batch {resume_i}")
        return resume_i, data

    def _handle_preempt(self, preempt: PreemptionHandler) -> None:
        """Grace-window final save: persist the current full TrainState
        through the existing checkpointer, bounded by ``preempt_grace``."""
        step = int(self.state.step)
        saved = False
        if self.checkpoint_dir:
            self._save_checkpoint()
            if self.async_checkpoint:
                self._drain_checkpoints(timeout=self.preempt_grace)
                saved = (self._checkpointer is not None
                         and self._checkpointer.latest_persisted_step()
                         == step)
            else:
                saved = True
        self.telemetry.emit(
            "preempt", step=step, signal=preempt.signal_name, saved=saved,
            grace_s=float(self.preempt_grace or 0.0),
        )
        self.log(f"preempted ({preempt.signal_name}): step {step} "
                 f"saved={saved}; stopping cleanly")

    def _emit_run_end(self, supervisor: Optional[TrainingSupervisor] = None
                      ) -> None:
        if not self.telemetry.enabled:
            return
        fields: Dict[str, Any] = {"status": self._status}
        try:
            if self.state is not None:
                fields["final_step"] = int(self.state.step)
                fields["skipped_steps"] = int(self.state.skipped)
        except Exception:
            pass  # state may be donated/deleted when aborting mid-step
        if self.history:
            fields["final_loss"] = float(
                self.history[-1].get(LOSS_KEY, float("nan")))
        if supervisor is not None:
            fields["rollbacks"] = supervisor.rollbacks
        self.telemetry.emit("run_end", **fields)

    # ------------------------------------------------------------------
    def fit_stages(
        self, stages: Sequence[Stage], *, data_seed: int = 0
    ) -> List[Dict[str, float]]:
        """Mixed-batch training: re-jit per stage, carry moments, re-warm-up."""
        if self.state is None:
            self.init()
        self._emit_run_start()
        self._status = "ok"
        try:
            self._fit_stages(stages, data_seed=data_seed)
        except BaseException as e:
            self._status = ("diverged" if isinstance(e, DivergenceError)
                            else "failed")
            raise
        finally:
            self._emit_run_end()
        return self.history

    def _fit_stages(self, stages: Sequence[Stage], *, data_seed: int) -> None:
        telem = self.telemetry.enabled
        # one wall clock across all stages, so fit_stages history rows carry
        # the same ``wall_s`` field as fit's and stay comparable
        t0 = time.perf_counter()
        for si, stage in enumerate(stages):
            self.log(
                f"== stage {si}: {stage.name} seq={stage.seq_len} "
                f"batch={stage.batch_size} steps={stage.steps} "
                f"lr={stage.learning_rate:.2e} warmup={stage.warmup_steps}"
            )
            self.telemetry.emit(
                "stage_start", stage=si, name=stage.name,
                seq_len=stage.seq_len, batch_size=stage.batch_size,
                steps=stage.steps, learning_rate=stage.learning_rate,
                warmup_steps=stage.warmup_steps,
            )
            opt = make_optimizer(
                self.model, self.tc, stage.schedule,
                param_specs=self._param_specs,
            )
            _, step_fn = make_train_step(
                self.model, self.tc, stage.schedule, optimizer=opt,
                param_specs=self._param_specs,
            )
            step_jit = self._jit_step(step_fn)
            if si > 0:
                # re-warm-up: keep moments, restart schedule counters
                self.state = TrainState(
                    self.state.params,
                    _reset_schedule_counts(self.state.opt_state),
                    self.state.step,
                    self.state.skipped,
                )
            data = DataPipeline(
                self.model.cfg, stage.batch_size, stage.seq_len, seed=data_seed + si
            )
            since_log = 0
            with use_sharding(self.shard_ctx):
                for i in range(stage.steps):
                    if telem and since_log == 0:
                        self.spans.start("step", sync=self.state)
                    batch = self._place_batch(next(data))
                    self.examples_seen += _batch_examples(batch)
                    self.state, metrics = step_jit(self.state, batch)
                    since_log += 1
                    if (i + 1) % self.log_every == 0 or i == stage.steps - 1:
                        m, per_layer = self._host_metrics(metrics)
                        step_s = (
                            self.spans.stop("step", sync=self.state,
                                            count=since_log)
                            if telem else 0.0
                        )
                        m["step"] = int(self.state.step)
                        m["examples_seen"] = self.examples_seen
                        m["wall_s"] = time.perf_counter() - t0
                        m["stage"] = si
                        self.history.append(m)
                        self.log(
                            f"[{stage.name}] step {m['step']:5d} "
                            f"loss {m.get('loss/total', 0.0):.4f}"
                        )
                        if telem:
                            self._log_step(m, per_layer, step_s, since_log)
                        since_log = 0
