"""Deterministic numerical fault injection for the robustness harness.

A :class:`FaultInjector` wraps a deterministic data iterator and stamps
per-batch *fault channels* — extra ``fault/*`` leaves shaped ``(B,)`` so
they slice and shard exactly like real batch leaves — keyed by **batch
ordinal** (position in the stream), not by ``state.step``: with the
skip-step guard on, a skipped step does not advance ``state.step``, so a
step-keyed fault would re-fire forever.

``make_train_step`` pops the channels out of the batch before the loss
(see :func:`split_faults`) and applies them to the token-mean gradients
in-jit (:func:`apply_grad_faults`):

* ``grad_nan`` / ``grad_inf`` — overwrite every gradient leaf with
  NaN/Inf, the exact signature of a poisoned microbatch; exercises the
  non-finite guard's skip path.
* ``grad_scale`` — multiply the gradients by a large factor.  The step
  stays finite, so the guard passes and the *optimizer moments* are
  corrupted (note LAMB's trust ratio bounds the parameter damage of any
  one step to ~lr·‖p‖ — gradient scaling alone cannot spike the loss).
* ``loss_spike`` — add ``scale`` to the reported ``loss/total`` metric
  in-jit: the deterministic observable of a divergence, exactly what the
  loss-spike supervisor watches.  Drives the rollback scenarios.
* ``batch_nan`` — poison the first float leaf of the batch itself at
  stamp time (host-side), upstream of the forward pass.

Injection is pure state machine: the same spec list over the same stream
produces the same stamps, and ``once`` semantics survive a rollback's
data-pipeline rebuild (the fired-set lives on the injector, not the
wrapped iterator).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

FAULT_PREFIX = "fault/"
GRAD_NAN_KEY = FAULT_PREFIX + "grad_nan"
GRAD_INF_KEY = FAULT_PREFIX + "grad_inf"
GRAD_SCALE_KEY = FAULT_PREFIX + "grad_scale"
LOSS_SPIKE_KEY = FAULT_PREFIX + "loss_spike"

KINDS = ("grad_nan", "grad_inf", "grad_scale", "loss_spike", "batch_nan")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: ``kind`` at batch ordinal ``at`` (0-based).

    ``at < 0`` fires on *every* batch (persistent fault — what drives the
    max-rollback diagnostic abort).  ``once=True`` (default) fires a
    non-negative ``at`` a single time even if the ordinal is replayed
    after a rollback.  ``scale`` is the ``grad_scale`` multiplier.
    """

    kind: str
    at: int
    scale: float = 1e6
    once: bool = True

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")


class FaultInjector:
    def __init__(self, faults: Iterable[FaultSpec]):
        self.faults: Tuple[FaultSpec, ...] = tuple(faults)
        self._fired: Dict[int, int] = {}

    def _active(self, ordinal: int):
        out = []
        for idx, f in enumerate(self.faults):
            if f.at >= 0 and f.at != ordinal:
                continue
            if f.at >= 0 and f.once and self._fired.get(idx, 0):
                continue
            self._fired[idx] = self._fired.get(idx, 0) + 1
            out.append(f)
        return out

    def stamp(self, batch: Dict[str, Any], ordinal: int) -> Dict[str, Any]:
        """Return ``batch`` plus the three grad-fault channels (always
        present, so the jit'd step sees one constant pytree structure);
        ``batch_nan`` faults poison the batch itself here instead."""
        active = self._active(ordinal)
        b = dict(batch)
        n = int(jax.tree.leaves(batch)[0].shape[0])
        nan_on = any(f.kind == "grad_nan" for f in active)
        inf_on = any(f.kind == "grad_inf" for f in active)
        scale = 1.0
        spike = 0.0
        for f in active:
            if f.kind == "grad_scale":
                scale *= f.scale
            if f.kind == "loss_spike":
                spike += f.scale
        b[GRAD_NAN_KEY] = np.full((n,), 1.0 if nan_on else 0.0, np.float32)
        b[GRAD_INF_KEY] = np.full((n,), 1.0 if inf_on else 0.0, np.float32)
        b[GRAD_SCALE_KEY] = np.full((n,), scale, np.float32)
        b[LOSS_SPIKE_KEY] = np.full((n,), spike, np.float32)
        for f in active:
            if f.kind != "batch_nan":
                continue
            poisoned = False
            for key in sorted(batch):
                leaf = np.asarray(batch[key])
                if np.issubdtype(leaf.dtype, np.floating):
                    leaf = leaf.copy()
                    leaf.reshape(-1)[0] = np.nan
                    b[key] = leaf
                    poisoned = True
                    break
            if not poisoned:
                raise ValueError(
                    "batch_nan fault: batch has no float leaf to poison "
                    f"(keys: {sorted(batch)})"
                )
        return b

    def wrap(self, data: Iterator[Dict[str, Any]]) -> Iterator[Dict[str, Any]]:
        """Yield ``data``'s batches with fault channels stamped; ordinals
        restart at 0 per wrapped stream (matching a rebuilt pipeline's
        fast-forward), while fired-once state persists across wraps."""

        def gen():
            for ordinal, batch in enumerate(data):
                yield self.stamp(batch, ordinal)

        return gen()


def split_faults(batch) -> Tuple[Any, Dict[str, jnp.ndarray]]:
    """Pop the ``fault/*`` channels out of a batch (jit-safe: structure is
    static).  Returns ``(clean_batch, faults)``; unfaulted batches pass
    through untouched with an empty dict."""
    if not isinstance(batch, dict) or not any(
        k.startswith(FAULT_PREFIX) for k in batch
    ):
        return batch, {}
    clean = {k: v for k, v in batch.items() if not k.startswith(FAULT_PREFIX)}
    faults = {k: v for k, v in batch.items() if k.startswith(FAULT_PREFIX)}
    return clean, faults


def apply_grad_faults(grads, faults: Dict[str, jnp.ndarray]):
    """Apply stamped fault channels to the gradient pytree (in-jit).

    The ``(B,)`` channels are reduced to scalars first (a global reduce
    under GSPMD, so every shard agrees), then broadcast over every leaf.
    """
    if not faults:
        return grads
    scale = faults.get(GRAD_SCALE_KEY)
    if scale is not None:
        s = jnp.max(scale.astype(jnp.float32))
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * s).astype(g.dtype), grads
        )
    for key, bad in ((GRAD_NAN_KEY, jnp.nan), (GRAD_INF_KEY, jnp.inf)):
        chan = faults.get(key)
        if chan is not None:
            on = jnp.max(chan.astype(jnp.float32)) > 0
            grads = jax.tree.map(
                lambda g, _on=on, _bad=bad: jnp.where(
                    _on, jnp.asarray(_bad, g.dtype), g
                ),
                grads,
            )
    return grads


def apply_loss_faults(metrics: Dict[str, Any],
                      faults: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    """Add any stamped ``loss_spike`` magnitude to the loss metric (in-jit).

    The spike rides the *observed* channel only — parameters and gradients
    are untouched — so a detector trip, the rollback, and the post-rollback
    recovery are all exercised deterministically.
    """
    chan = faults.get(LOSS_SPIKE_KEY)
    if chan is None or "loss/total" not in metrics:
        return metrics
    metrics = dict(metrics)
    metrics["loss/total"] = (
        metrics["loss/total"] + jnp.max(chan.astype(jnp.float32))
    )
    return metrics
