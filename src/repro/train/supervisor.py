"""TrainingSupervisor: host-side loss-spike watchdog with checkpoint rollback.

The in-jit non-finite guard (``TrainConfig.skip_nonfinite``) catches the
*loud* failure — NaN/Inf loss or grads — by skipping the step.  The silent
one is divergence: every value finite, the loss climbing away (the regime
You et al. motivate LARS/LAMB with: plain large-batch momentum diverges).
The supervisor watches the per-step loss with a **median + MAD z-score**
over a rolling window of healthy observations — robust statistics, so the
spike itself cannot drag the threshold up the way a mean/std window would
— and on a trip tells the Trainer to roll back to the last *validated*
checkpoint and resume the data stream **past** the suspect window.

Validation matters: a checkpoint written at step ``s`` holds the params
that produce the loss observed one step later, so a healthy observation at
step ``s`` retroactively validates the step-``s`` checkpoint.  A save that
raced ahead of a poisoned update is therefore never a rollback target —
the Trainer restores the newest checkpoint with ``step <= last_good``.

Trips:

* ``loss_spike`` — robust z-score above ``spike_zmax`` AND a relative jump
  (two gates, so a near-constant loss window cannot false-trip on noise);
* ``nonfinite_loss`` — a non-finite loss observed with the guard off (or a
  non-finite metric that slipped past it): params are already poisoned;
* ``nonfinite_budget`` — ``skip_budget`` *consecutive* guard skips: the
  stream or the state is persistently producing non-finite steps and
  skipping forward is no longer making progress.

``max_rollbacks`` bounds the retry loop; exceeding it raises
:class:`DivergenceError` carrying the diagnostics (recent losses, skip and
rollback counts) — the clean abort, instead of looping forever on a run
that cannot be saved.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


class DivergenceError(RuntimeError):
    """Training diverged beyond what rollback can repair (clean abort)."""

    def __init__(self, message: str, diagnostics: Optional[Dict[str, Any]] = None):
        super().__init__(message)
        self.diagnostics = diagnostics or {}


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    spike_window: int = 32        # rolling window of healthy losses
    spike_zmax: float = 8.0       # robust z-score trip threshold
    min_history: int = 8          # observations before the detector arms
    min_rel_jump: float = 0.5     # AND-gate: loss > med + jump*max(|med|,1)
    skip_budget: int = 3          # consecutive guard skips before a trip
    max_rollbacks: int = 3        # rollbacks before the diagnostic abort


class SpikeDetector:
    """Windowed robust (median + MAD) spike detector over a loss stream.

    ``observe(loss)`` returns True on a spike.  Non-finite losses always
    count as spikes; spiking values never enter the window, so a slow
    divergence cannot normalize itself into the statistics.
    """

    def __init__(self, window: int = 32, zmax: float = 8.0,
                 min_history: int = 8, min_rel_jump: float = 0.5):
        if min_history < 2:
            raise ValueError("min_history must be >= 2")
        self.zmax = float(zmax)
        self.min_history = int(min_history)
        self.min_rel_jump = float(min_rel_jump)
        self._window: deque = deque(maxlen=int(window))

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])

    def stats(self) -> Tuple[float, float]:
        """(median, MAD) of the current window."""
        xs = list(self._window)
        med = self._median(xs)
        mad = self._median([abs(x - med) for x in xs])
        return med, mad

    def observe(self, loss: float) -> bool:
        loss = float(loss)
        if not math.isfinite(loss):
            return True
        if len(self._window) < self.min_history:
            self._window.append(loss)
            return False
        med, mad = self.stats()
        # 1.4826·MAD ≈ σ for gaussian noise; the floor keeps a constant
        # window from making the z-score infinite on any wiggle — the
        # relative-jump AND-gate is what actually rejects small noise
        z = (loss - med) / (1.4826 * mad + 1e-12)
        jump = loss > med + self.min_rel_jump * max(abs(med), 1.0)
        if z > self.zmax and jump:
            return True
        self._window.append(loss)
        return False

    def reset(self) -> None:
        self._window.clear()


class TrainingSupervisor:
    """Folds per-step host observations into trip/rollback decisions.

    The Trainer calls :meth:`observe` once per completed step with the
    host-fetched loss and the state's cumulative ``skipped`` counter, and
    acts on the returned trip reason (None = healthy).  ``last_good`` is
    the newest checkpoint step a healthy observation has validated — the
    rollback target bound.
    """

    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self.detector = SpikeDetector(
            window=cfg.spike_window, zmax=cfg.spike_zmax,
            min_history=cfg.min_history, min_rel_jump=cfg.min_rel_jump,
        )
        self.rollbacks = 0
        self.consecutive_skips = 0
        self.last_good = -1
        self._last_skipped = 0
        self._recent: deque = deque(maxlen=max(cfg.spike_window, 8))

    def observe(self, step: int, loss: float,
                skipped_total: int) -> Optional[str]:
        """One post-step observation; returns a trip reason or None.

        ``step`` is the state's step counter *after* the update (the loss
        was computed on the pre-update params), ``skipped_total`` the
        cumulative guard-skip counter.
        """
        step, skipped_total = int(step), int(skipped_total)
        loss = float(loss)
        self._recent.append({"step": step, "loss": loss,
                             "skipped_total": skipped_total})
        delta = skipped_total - self._last_skipped
        self._last_skipped = skipped_total
        if delta > 0:
            self.consecutive_skips += 1
            if self.consecutive_skips >= self.cfg.skip_budget:
                return "nonfinite_budget"
            return None
        self.consecutive_skips = 0
        if not math.isfinite(loss):
            # guard off (or a metric the guard does not cover): the update
            # that produced this loss already poisoned the params
            return "nonfinite_loss"
        if self.detector.observe(loss):
            return "loss_spike"
        # healthy loss on pre-update params: validates the state as of one
        # step earlier — and hence any checkpoint at step <= step - 1
        self.last_good = max(self.last_good, step - 1)
        return None

    def note_rollback(self, reason: str) -> None:
        """Count a rollback; raise :class:`DivergenceError` past the budget."""
        self.rollbacks += 1
        if self.rollbacks > self.cfg.max_rollbacks:
            raise DivergenceError(
                f"diverged: {reason} persisted through "
                f"{self.cfg.max_rollbacks} rollback(s)",
                self.diagnostics(reason),
            )

    def after_rollback(self, skipped_total: int) -> None:
        """Re-sync after the Trainer restored state: clear the window (the
        loss level may legitimately differ at the restored step) and re-base
        the skip counter on the restored state's counter."""
        self.detector.reset()
        self.consecutive_skips = 0
        self._last_skipped = int(skipped_total)

    def diagnostics(self, reason: str = "") -> Dict[str, Any]:
        med, mad = (self.detector.stats() if self.detector._window
                    else (float("nan"), float("nan")))
        return {
            "reason": reason,
            "rollbacks": self.rollbacks,
            "consecutive_skips": self.consecutive_skips,
            "last_good_step": self.last_good,
            "window_median": med,
            "window_mad": mad,
            "recent": list(self._recent),
        }
