from repro.train.faults import FaultInjector, FaultSpec
from repro.train.loss import IGNORE, cross_entropy, lm_loss, loss_for, masked_prediction_loss
from repro.train.preempt import PreemptionHandler
from repro.train.step import (
    GUARD_KEY,
    TrainState,
    make_loss_fn,
    make_optimizer,
    make_train_step,
    tree_all_finite,
)
from repro.train.supervisor import (
    DivergenceError,
    SpikeDetector,
    SupervisorConfig,
    TrainingSupervisor,
)
from repro.train.trainer import Trainer

__all__ = [
    "DivergenceError",
    "FaultInjector",
    "FaultSpec",
    "GUARD_KEY",
    "IGNORE",
    "PreemptionHandler",
    "SpikeDetector",
    "SupervisorConfig",
    "TrainState",
    "Trainer",
    "TrainingSupervisor",
    "cross_entropy",
    "lm_loss",
    "loss_for",
    "make_loss_fn",
    "make_optimizer",
    "make_train_step",
    "masked_prediction_loss",
    "tree_all_finite",
]
