from repro.train.loss import IGNORE, cross_entropy, lm_loss, loss_for, masked_prediction_loss
from repro.train.step import TrainState, make_loss_fn, make_optimizer, make_train_step
from repro.train.trainer import Trainer

__all__ = [
    "IGNORE",
    "TrainState",
    "Trainer",
    "cross_entropy",
    "lm_loss",
    "loss_for",
    "make_loss_fn",
    "make_optimizer",
    "make_train_step",
    "masked_prediction_loss",
]
