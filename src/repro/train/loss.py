"""Loss functions: causal LM, masked prediction (HuBERT), MoE aux, MTP.

Two head paths share every loss:

  * **dense** — the model returns ``(B, S, V)`` logits and
    :func:`cross_entropy` takes an fp32 ``log_softmax`` over them;
  * **fused** (``cfg.use_fused_ce_head``) — the model returns final hidden
    states, :func:`gather_supervised` packs the ``labels >= 0`` positions
    into a fixed-size ``(B, P, D)`` buffer *before* the vocab projection,
    and ``kernels.fused_ce`` streams vocab chunks through projection +
    online log-sum-exp so the logits tensor never exists (see
    docs/kernels.md).  MLM supervises ~15% of positions, so this cuts the
    LM-head FLOPs and activations ~6.7×.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import fused_ce

IGNORE = -1  # label value for unsupervised positions


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over positions with label >= 0.  Returns (loss, accuracy).

    The log-softmax is always taken in fp32 so bf16 logits keep full dynamic
    range in the reduction (mixed-precision safe).
    """
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(ll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == safe).astype(jnp.float32) * mask) / denom
    return loss, acc


# ---------------------------------------------------------------------------
# fused head: gather supervised positions, then chunked-vocab CE
# ---------------------------------------------------------------------------

def mlm_buffer_size(cfg: ModelConfig, seq_len: int) -> int:
    """The fused head's gather-buffer size P (static for jit).

    Delegates to :meth:`ModelConfig.mlm_buffer_size` — the same bound the
    synthetic MLM pipeline caps per-row target counts at, so data and loss
    can never disagree about P.  Unmasked objectives (``mask_ratio == 0``:
    causal LM, prefix-LM) supervise every position, so P = S and the gather
    degenerates to the identity permutation.
    """
    return cfg.mlm_buffer_size(seq_len)


def gather_supervised(
    hidden: jnp.ndarray,   # (B, S, D)
    labels: jnp.ndarray,   # (B, S) with IGNORE marking unsupervised positions
    p: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pack the ``labels >= 0`` positions into a fixed-size (B, P, ...) buffer.

    Returns ``(hidden_sel (B,P,D), labels_sel (B,P), valid (B,P) bool,
    count (B,))`` — supervised positions first (stable order), pad slots
    marked invalid.  Static shapes: P is a Python int, so the result is
    jit-friendly regardless of how many positions each example supervises.
    Overflow (``count > p``) is NOT truncated here; callers must check
    ``count`` (see :func:`fused_cross_entropy`).
    """
    b, s = labels.shape
    mask = labels >= 0
    count = jnp.sum(mask.astype(jnp.int32), axis=-1)
    # stable argsort of the inverted mask puts supervised positions first,
    # in their original order
    order = jnp.argsort(jnp.logical_not(mask), axis=-1, stable=True)
    idx = order[:, :p]
    hidden_sel = jnp.take_along_axis(hidden, idx[..., None], axis=1)
    labels_sel = jnp.take_along_axis(labels, idx, axis=1)
    valid = jax.lax.broadcasted_iota(jnp.int32, (b, p), 1) < count[:, None]
    return hidden_sel, jnp.where(valid, labels_sel, IGNORE), valid, count


def fused_cross_entropy(
    hidden: jnp.ndarray,   # (B, S, D) final hidden states
    labels: jnp.ndarray,   # (B, S) with IGNORE
    w: jnp.ndarray,        # (V, D) vocab projection (embedding layout)
    *,
    max_positions: int,
    backend: str = "auto",
    block_v: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-head (loss, accuracy): gather → chunked-vocab CE, no logits.

    Semantics match :func:`cross_entropy` on the same labels (token-mean
    over ``labels >= 0``; zero supervision → loss 0, acc 0, zero grads).

    A sequence with more than ``max_positions`` supervised positions cannot
    be represented in the fixed gather buffer.  Called eagerly (concrete
    labels) this raises a ValueError; under jit the loss is poisoned to NaN
    — never a silent truncation.
    """
    b, s, d = hidden.shape
    p = max(1, min(max_positions, s))
    if not isinstance(labels, jax.core.Tracer):
        mx = int(jnp.max(jnp.sum((labels >= 0).astype(jnp.int32), axis=-1)))
        if mx > p:
            raise ValueError(
                f"a sequence supervises {mx} positions but the fused-CE "
                f"gather buffer holds P={p}; raise "
                f"ModelConfig.mlm_max_predictions (or cap masking in the "
                f"data pipeline) — refusing to silently truncate"
            )
    hidden_sel, labels_sel, valid, count = gather_supervised(hidden, labels, p)
    nll, correct = fused_ce(
        hidden_sel.reshape(b * p, d), w, labels_sel.reshape(b * p),
        backend=backend, block_v=block_v,
    )
    wrow = valid.reshape(b * p).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(wrow), 1.0)
    loss = jnp.sum(nll * wrow) / denom
    acc = jnp.sum(correct * wrow) / denom
    # under jit the eager check above never ran: poison instead of
    # truncating.  Multiplicative so the NaN propagates through the
    # *backward* too (a where-select would zero the taken branch's
    # cotangent, silently dropping the CE gradients on overflow)
    poison = jnp.where(jnp.any(count > p), jnp.float32(jnp.nan),
                       jnp.float32(1.0))
    return loss * poison, acc * poison


def head_weights(params, cfg: ModelConfig) -> jnp.ndarray:
    """The vocab projection in (V, D) embedding layout for the fused head."""
    if cfg.tie_embeddings:
        return params["embed"]
    return params["unembed"].T


def check_fused_ce_supported(cfg: ModelConfig) -> None:
    """Clear error for configs the fused head cannot express."""
    if cfg.family in ("hybrid", "ssm"):
        raise ValueError(
            f"use_fused_ce_head is not supported for family {cfg.family!r} "
            "(the hidden-states forward path is transformer-only)"
        )
    if cfg.logit_softcap:
        raise ValueError(
            "use_fused_ce_head cannot apply logit_softcap (the fused CE "
            "streams raw projections); disable one of the two"
        )
    if cfg.frontend == "audio_stub" and cfg.mlm_max_predictions is None:
        raise ValueError(
            "use_fused_ce_head with audio_stub needs an explicit "
            "ModelConfig.mlm_max_predictions: Bernoulli span masks are not "
            "bounded by ceil(mask_ratio * seq) (that is their mean), so the "
            "default gather buffer would overflow on most batches"
        )


def _masked_ce(
    logits: Optional[jnp.ndarray],
    hidden: Optional[jnp.ndarray],
    labels: jnp.ndarray,
    cfg: ModelConfig,
    params,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense or fused CE over ``labels >= 0`` — one switch for every loss."""
    if hidden is None:
        return cross_entropy(logits, labels)
    if params is None:
        raise ValueError("the fused CE head needs params (vocab projection)")
    return fused_cross_entropy(
        hidden, labels, head_weights(params, cfg),
        max_positions=mlm_buffer_size(cfg, labels.shape[-1]),
        backend=cfg.fused_ce_backend,
    )


def supervised_token_count(labels: jnp.ndarray) -> jnp.ndarray:
    """Number of positions contributing to the CE denominator (label >= 0).

    Gradient accumulation weights each microbatch's mean loss/grad by this
    count so that k microbatches reproduce the single full-batch token mean
    even when masking (MLM / HuBERT) gives slices unequal supervision.
    """
    return jnp.sum((labels >= 0).astype(jnp.float32))


def lm_loss(
    logits: Optional[jnp.ndarray],
    batch: Dict[str, jnp.ndarray],
    aux: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    params=None,
    hidden: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE + MoE aux losses (+ optional MTP head loss).

    ``batch["labels"]`` is aligned with logits positions (label[t] is the
    target for position t); IGNORE(-1) marks unsupervised positions.  With
    ``hidden`` given (fused head), the main CE runs gather + chunked-vocab
    CE on the final hidden states instead of dense logits; the MTP branch
    keeps its own (dense) head either way.
    """
    labels = batch["labels"]
    mtp_hidden = aux.get("mtp_hidden")
    ce, acc = _masked_ce(logits, hidden, labels, cfg, params)
    total = ce
    metrics = {"loss/ce": ce, "accuracy": acc}

    if "moe_lb_loss" in aux:
        lb = aux["moe_lb_loss"]
        total = total + cfg.router_aux_coef * lb
        metrics["loss/moe_lb"] = lb
        metrics["moe/drop_fraction"] = aux.get("moe_drop_fraction", jnp.asarray(0.0))
    if "moe_z_loss" in aux:
        total = total + cfg.router_z_coef * aux["moe_z_loss"]
        metrics["loss/moe_z"] = aux["moe_z_loss"]

    if cfg.use_mtp and mtp_hidden is not None and params is not None:
        from repro.models.transformer import mtp_logits

        mlogits = mtp_logits(params, mtp_hidden, batch, cfg)
        # MTP depth-1: predict label shifted one further; last position invalid
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], IGNORE)], axis=1
        )
        mtp_ce, _ = cross_entropy(mlogits, mtp_labels)
        total = total + cfg.mtp_loss_coef * mtp_ce
        metrics["loss/mtp"] = mtp_ce

    metrics["loss/total"] = total
    metrics["tokens/supervised"] = supervised_token_count(labels)
    return total, metrics


def masked_prediction_loss(
    logits: Optional[jnp.ndarray],
    batch: Dict[str, jnp.ndarray],
    aux: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    params=None,
    hidden: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """HuBERT-style: CE on masked frames only (targets = cluster ids).

    The fused path (``hidden``) needs ``cfg.mlm_max_predictions`` sized for
    the masking distribution: HuBERT span masks are Bernoulli, so their
    per-row count is not bounded by ``ceil(mask_ratio · S)``.
    """
    labels = jnp.where(batch["mask"], batch["labels"], IGNORE)
    ce, acc = _masked_ce(logits, hidden, labels, cfg, params)
    return ce, {
        "loss/ce": ce, "accuracy": acc, "loss/total": ce,
        "tokens/supervised": supervised_token_count(labels),
    }


def loss_for(cfg: ModelConfig):
    if cfg.frontend == "audio_stub":
        return masked_prediction_loss
    return lm_loss
