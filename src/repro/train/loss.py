"""Loss functions: causal LM, masked prediction (HuBERT), MoE aux, MTP."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

IGNORE = -1  # label value for unsupervised positions


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over positions with label >= 0.  Returns (loss, accuracy).

    The log-softmax is always taken in fp32 so bf16 logits keep full dynamic
    range in the reduction (mixed-precision safe).
    """
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(ll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == safe).astype(jnp.float32) * mask) / denom
    return loss, acc


def supervised_token_count(labels: jnp.ndarray) -> jnp.ndarray:
    """Number of positions contributing to the CE denominator (label >= 0).

    Gradient accumulation weights each microbatch's mean loss/grad by this
    count so that k microbatches reproduce the single full-batch token mean
    even when masking (MLM / HuBERT) gives slices unequal supervision.
    """
    return jnp.sum((labels >= 0).astype(jnp.float32))


def lm_loss(
    logits: jnp.ndarray,
    batch: Dict[str, jnp.ndarray],
    aux: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    params=None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE + MoE aux losses (+ optional MTP head loss).

    ``batch["labels"]`` is aligned with logits positions (label[t] is the
    target for position t); IGNORE(-1) marks unsupervised positions.
    """
    labels = batch["labels"]
    mtp_hidden = aux.pop("mtp_hidden", None)
    ce, acc = cross_entropy(logits, labels)
    total = ce
    metrics = {"loss/ce": ce, "accuracy": acc}

    if "moe_lb_loss" in aux:
        lb = aux["moe_lb_loss"]
        total = total + cfg.router_aux_coef * lb
        metrics["loss/moe_lb"] = lb
        metrics["moe/drop_fraction"] = aux.get("moe_drop_fraction", jnp.asarray(0.0))
    if "moe_z_loss" in aux:
        total = total + cfg.router_z_coef * aux["moe_z_loss"]
        metrics["loss/moe_z"] = aux["moe_z_loss"]

    if cfg.use_mtp and mtp_hidden is not None and params is not None:
        from repro.models.transformer import mtp_logits

        mlogits = mtp_logits(params, mtp_hidden, batch, cfg)
        # MTP depth-1: predict label shifted one further; last position invalid
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], IGNORE)], axis=1
        )
        mtp_ce, _ = cross_entropy(mlogits, mtp_labels)
        total = total + cfg.mtp_loss_coef * mtp_ce
        metrics["loss/mtp"] = mtp_ce

    metrics["loss/total"] = total
    metrics["tokens/supervised"] = supervised_token_count(labels)
    return total, metrics


def masked_prediction_loss(
    logits: jnp.ndarray,
    batch: Dict[str, jnp.ndarray],
    aux: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    params=None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """HuBERT-style: CE on masked frames only (targets = cluster ids)."""
    labels = jnp.where(batch["mask"], batch["labels"], IGNORE)
    ce, acc = cross_entropy(logits, labels)
    return ce, {
        "loss/ce": ce, "accuracy": acc, "loss/total": ce,
        "tokens/supervised": supervised_token_count(labels),
    }


def loss_for(cfg: ModelConfig):
    if cfg.frontend == "audio_stub":
        return masked_prediction_loss
    return lm_loss
