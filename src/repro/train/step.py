"""Train-step factory: value_and_grad → optimizer → apply, with optional
gradient-accumulation microbatching.

``make_optimizer`` wires the model's pytree metadata (weight-decay mask,
trust-ratio mask, stacked-layer axes) into the paper's optimizers so that
LAMB's layerwise semantics survive scanned parameter stacks.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import core, optim
from repro.configs.base import ModelConfig, TrainConfig
from repro.models.api import Model
from repro.train.loss import loss_for


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def make_optimizer(
    model: Model, tc: TrainConfig, schedule=None
) -> optim.GradientTransformation:
    lr = schedule if schedule is not None else tc.learning_rate
    wd_mask = model.wd_mask()
    trust_mask = model.trust_mask()
    layer_axes = model.layer_axes()
    common = dict(
        wd_mask=wd_mask, trust_mask=trust_mask, layer_axes=layer_axes,
        phi_bounds=tc.phi_bounds,
    )
    name = tc.optimizer
    if name == "lamb":
        return core.lamb(
            lr, tc.b1, tc.b2, tc.eps, tc.weight_decay,
            bias_correction=tc.bias_correction,
            grad_clip_norm=tc.grad_clip_norm,
            moment_dtype=tc.moment_dtype, **common,
        )
    if name == "nlamb":
        return core.nlamb(lr, weight_decay=tc.weight_decay,
                          grad_clip_norm=tc.grad_clip_norm, **common)
    if name == "nnlamb":
        return core.nnlamb(lr, weight_decay=tc.weight_decay,
                           grad_clip_norm=tc.grad_clip_norm, **common)
    if name == "lars":
        return core.lars(lr, momentum=tc.b1, weight_decay=tc.weight_decay, **common)
    if name == "adam":
        return optim.adam(lr, tc.b1, tc.b2, tc.eps)
    if name == "adamw":
        return optim.adamw(lr, tc.b1, tc.b2, tc.eps, tc.weight_decay, wd_mask)
    if name == "adagrad":
        return optim.adagrad(lr)
    if name == "momentum":
        return optim.momentum(lr, tc.b1, tc.weight_decay, wd_mask)
    raise ValueError(f"unknown optimizer {name!r}")


def make_loss_fn(model: Model) -> Callable:
    loss_impl = loss_for(model.cfg)

    def loss_fn(params, batch):
        logits, aux = model.apply(params, batch)
        return loss_impl(logits, batch, aux, model.cfg, params=params)

    return loss_fn


def _microbatch_grads(loss_fn, params, batch, n_micro: int):
    """Sequential grad accumulation over `n_micro` equal batch slices."""

    def slice_batch(b, i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0
            ),
            b,
        )

    def body(carry, i):
        g_acc, m_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, slice_batch(batch, i)
        )
        g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
        m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
        return (g_acc, m_acc), None

    (l0, m0), g0 = jax.value_and_grad(loss_fn, has_aux=True)(
        params, slice_batch(batch, 0)
    )
    if n_micro == 1:
        return g0, m0
    (g, m), _ = jax.lax.scan(
        body, (g0, m0), jnp.arange(1, n_micro)
    )
    inv = 1.0 / n_micro
    return (
        jax.tree.map(lambda x: x * inv, g),
        jax.tree.map(lambda x: x * inv, m),
    )


def make_train_step(
    model: Model,
    tc: TrainConfig,
    schedule=None,
    *,
    optimizer: Optional[optim.GradientTransformation] = None,
) -> Tuple[Callable, Callable]:
    """Returns (init_fn(rng) -> TrainState, step_fn(state, batch) -> (state, metrics))."""
    opt = optimizer if optimizer is not None else make_optimizer(model, tc, schedule)
    loss_fn = make_loss_fn(model)
    n_micro = tc.microbatch or 1

    def init_fn(rng) -> TrainState:
        params = model.init(rng)
        return TrainState(params, opt.init(params), jnp.zeros([], jnp.int32))

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = _microbatch_grads(loss_fn, state.params, batch, n_micro)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = _global_norm(grads)
        if tc.log_trust_ratios:
            metrics.update(
                core.summarize_trust_ratios(
                    core.trust_ratio_tree(
                        state.params, updates, layer_axes=model.layer_axes(),
                        phi_bounds=tc.phi_bounds,
                    )
                )
            )
        return TrainState(params, opt_state, state.step + 1), metrics

    return init_fn, step_fn


def _global_norm(tree):
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))
