"""Train-step factory: the large-batch scaling path.

``make_train_step`` assembles the paper's recipe into one jit-able step:

  * **gradient accumulation** — ``lax.scan`` over ``tc.grad_accum_steps``
    microbatch slices, so ``global_batch = microbatch × accum × DP`` on fixed
    activation memory.  Each slice's mean loss/grad is weighted by its
    supervised-token count, so k microbatches reproduce the single
    full-batch token mean exactly even under MLM/HuBERT masking.
  * **mixed precision** — ``tc.precision="bf16"`` casts the fp32 master
    params to bf16 *inside* the loss (activations and matmuls run in bf16,
    gradients flow back to fp32 masters); optimizer moments and every norm
    reduction in the trust ratio stay fp32 (see core/strategy, optim/base).
  * **fused LAMB** — ``tc.use_fused_lamb`` swaps the unfused
    ``scale_by_adam → trust-ratio → -lr`` transform chain (≈21 N optimizer
    traffic) for the fused per-leaf update (Pallas kernel on TPU, single
    fused XLA expression elsewhere; ≈10 N), parity-checked per layer.
  * **fused MLM head** — ``cfg.use_fused_ce_head`` (default on for
    bert-large) makes the loss gather supervised positions before the vocab
    projection and stream the CE over vocab chunks, so no ``(B, S, V)``
    logits tensor is ever materialized (see ``make_loss_fn`` / train/loss).

``make_optimizer`` wires the model's pytree metadata (weight-decay mask,
trust-ratio mask, stacked-layer axes) into the paper's optimizers so that
LAMB's layerwise semantics survive scanned parameter stacks.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import core, nn, optim
from repro.configs.base import ModelConfig, TrainConfig
from repro.kernels import (
    fused_lamb,
    fused_lamb_init,
    make_fused_lamb_step,
    resolve_fused_backend,
)
from repro.models.api import Model
from repro.telemetry.trust import PER_LAYER_KEY
from repro.train.faults import (
    apply_grad_faults,
    apply_loss_faults,
    split_faults,
)
from repro.train.loss import check_fused_ce_supported, loss_for

# Metric key carrying each microbatch's supervised-token count (set by the
# loss functions); drives token-weighted accumulation below.
TOKEN_WEIGHT_KEY = "tokens/supervised"

# Metric key the non-finite guard reports under: 1.0 when the step was
# skipped (state passed through unchanged), 0.0 otherwise.  Only present
# with ``tc.skip_nonfinite``.
GUARD_KEY = "nonfinite/skip"

LOSS_KEY = "loss/total"


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    # cumulative non-finite-guard skips; persisted with the checkpoint so a
    # resume can fast-forward the data stream by step + skipped *batches*
    # (a skipped step consumed a batch without advancing ``step``)
    skipped: jnp.ndarray


def tree_all_finite(tree, *extra) -> jnp.ndarray:
    """One fused all-finite reduction over a pytree (+ extra leaves).

    Returns a scalar bool.  Under GSPMD the per-leaf ``jnp.all`` reductions
    stay *global* — sharded leaves contribute collectives, so every device
    agrees on the verdict (required: the skip select must be uniform).
    Integer leaves are finite by definition (``jnp.isfinite`` handles them).
    """
    leaves = list(jax.tree.leaves(tree)) + [x for x in extra if x is not None]
    if not leaves:
        return jnp.asarray(True)
    oks = [jnp.all(jnp.isfinite(x)) for x in leaves]
    return jnp.all(jnp.stack(oks)) if len(oks) > 1 else oks[0]


def _wants_fused(model: Model, tc: TrainConfig) -> bool:
    return bool(tc.use_fused_lamb or model.cfg.use_fused_lamb_kernel)


def _check_fused_supported(tc: TrainConfig) -> None:
    if not tc.bias_correction or tc.moment_dtype is not None:
        raise ValueError(
            "fused LAMB supports bias-corrected fp32 moments only; "
            "unset use_fused_lamb or bias_correction/moment_dtype"
        )


def make_optimizer(
    model: Model, tc: TrainConfig, schedule=None, *, param_specs=None
) -> optim.GradientTransformation:
    """Build the configured optimizer with the model's layerwise metadata.

    ``param_specs`` (a PartitionSpec tree from ``sharding.specs_for``) makes
    the fused-LAMB path sharding-aware: FSDP/TP-sharded leaves fall back
    per-leaf from the Pallas kernel to the fused-XLA update, whose
    trust-ratio norm reductions GSPMD keeps globally correct.

    Invariant: the returned transformation consumes *token-mean* fp32 grads
    and returns parameter deltas for ``optim.apply_updates``, on both the
    fused and unfused LAMB paths.
    """
    lr = schedule if schedule is not None else tc.learning_rate
    wd_mask = model.wd_mask()
    trust_mask = model.trust_mask()
    layer_axes = model.layer_axes()
    common = dict(
        wd_mask=wd_mask, trust_mask=trust_mask, layer_axes=layer_axes,
        phi_bounds=tc.phi_bounds,
    )
    name = tc.optimizer
    if name == "lamb" and _wants_fused(model, tc):
        _check_fused_supported(tc)
        return fused_lamb(
            lr, tc.b1, tc.b2, tc.eps, tc.weight_decay,
            grad_clip_norm=tc.grad_clip_norm,
            backend=tc.fused_backend, param_specs=param_specs, **common,
        )
    if name == "lamb":
        return core.lamb(
            lr, tc.b1, tc.b2, tc.eps, tc.weight_decay,
            bias_correction=tc.bias_correction,
            grad_clip_norm=tc.grad_clip_norm,
            moment_dtype=tc.moment_dtype, **common,
        )
    if name == "lans":
        return core.lans(
            lr, tc.b1, tc.b2, tc.eps, tc.weight_decay,
            bias_correction=tc.bias_correction,
            grad_clip_norm=tc.grad_clip_norm,
            moment_dtype=tc.moment_dtype, **common,
        )
    if name == "nlamb":
        return core.nlamb(lr, weight_decay=tc.weight_decay,
                          grad_clip_norm=tc.grad_clip_norm, **common)
    if name == "nnlamb":
        return core.nnlamb(lr, weight_decay=tc.weight_decay,
                           grad_clip_norm=tc.grad_clip_norm, **common)
    if name == "lars":
        return core.lars(lr, momentum=tc.b1, weight_decay=tc.weight_decay, **common)
    if name == "adam":
        return optim.adam(lr, tc.b1, tc.b2, tc.eps)
    if name == "adamw":
        return optim.adamw(lr, tc.b1, tc.b2, tc.eps, tc.weight_decay, wd_mask)
    if name == "adagrad":
        return optim.adagrad(lr)
    if name == "momentum":
        return optim.momentum(lr, tc.b1, tc.weight_decay, wd_mask)
    raise ValueError(f"unknown optimizer {name!r}")


def make_loss_fn(
    model: Model,
    compute_dtype: Optional[str] = None,
    *,
    use_fused_ce: Optional[bool] = None,
) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics) for this model's family.

    ``compute_dtype`` (e.g. ``"bfloat16"``) casts params inside the loss so
    the forward/backward run in low precision while ``params`` — and hence
    the gradients that flow back through the cast — stay fp32 masters.
    (The train step instead casts once *outside* the accumulation scan and
    passes ``compute_dtype=None`` here, amortizing the cast over microbatches;
    the gradients w.r.t. the cast copy are identical either way.)

    ``use_fused_ce`` overrides ``cfg.use_fused_ce_head``: when on, the model
    returns final hidden states instead of ``(B, S, V)`` logits and the loss
    runs the fused MLM head — gather supervised positions, then chunked-vocab
    CE (``kernels/fused_ce.py``) — so the logits tensor never exists.
    """
    cfg = model.cfg
    fused_ce_head = cfg.use_fused_ce_head if use_fused_ce is None else use_fused_ce
    if fused_ce_head:
        check_fused_ce_supported(cfg)
    loss_impl = loss_for(cfg)

    def loss_fn(params, batch):
        if fused_ce_head:
            # cast once here (not inside apply) so the loss's vocab
            # projection sees the same compute-dtype copy the forward ran
            # on — otherwise the mixed-precision policy would silently not
            # apply to the fused head's matmuls
            if compute_dtype is not None:
                params = nn.cast_tree(params, jnp.dtype(compute_dtype))
            hidden, aux = model.apply(params, batch, return_hidden=True)
            return loss_impl(None, batch, aux, cfg, params=params, hidden=hidden)
        logits, aux = model.apply(params, batch, compute_dtype=compute_dtype)
        return loss_impl(logits, batch, aux, cfg, params=params)

    return loss_fn


def _microbatch_grads(loss_fn, params, batch, n_micro: int):
    """Token-weighted sequential grad accumulation over ``n_micro`` slices.

    Returns fp32 grads equal to the full-batch token-mean gradient:
    ``g = Σ_i w_i g_i / Σ_i w_i`` with ``w_i`` the slice's supervised-token
    count (uniform weights when the loss reports none).  Metrics are averaged
    with the same weights, except ``tokens/supervised`` which is summed.
    """

    for x in jax.tree.leaves(batch):
        if x.shape[0] % n_micro:
            raise ValueError(
                f"global batch {x.shape[0]} is not divisible by "
                f"accum_steps {n_micro}; remainder examples would be dropped"
            )

    def slice_batch(b, i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro, 0
            ),
            b,
        )

    def one(i):
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, slice_batch(batch, i)
        )
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        w = metrics.get(TOKEN_WEIGHT_KEY, jnp.asarray(1.0, jnp.float32))
        return g, metrics, w

    g0, m0, w0 = one(0)
    if n_micro == 1:
        return g0, m0

    def body(carry, i):
        g_acc, m_acc, w_acc = carry
        g, m, w = one(i)
        g_acc = jax.tree.map(lambda a, b: a + w * b, g_acc, g)
        m_acc = jax.tree.map(lambda a, b: a + w * b, m_acc, m)
        return (g_acc, m_acc, w_acc + w), None

    g0w = jax.tree.map(lambda x: w0 * x, g0)
    m0w = jax.tree.map(lambda x: w0 * x, m0)
    (g, m, w), _ = jax.lax.scan(body, (g0w, m0w, w0), jnp.arange(1, n_micro))
    inv = 1.0 / w
    metrics = jax.tree.map(lambda x: x * inv, m)
    if TOKEN_WEIGHT_KEY in metrics:
        metrics[TOKEN_WEIGHT_KEY] = w  # total over the global batch, not mean
    return jax.tree.map(lambda x: x * inv, g), metrics


def make_train_step(
    model: Model,
    tc: TrainConfig,
    schedule=None,
    *,
    optimizer: Optional[optim.GradientTransformation] = None,
    param_specs=None,
) -> Tuple[Callable, Callable]:
    """Returns (init_fn(rng) -> TrainState, step_fn(state, batch) -> (state, metrics)).

    ``step_fn`` consumes the *global* batch; accumulation slices it into
    ``tc.grad_accum_steps`` microbatches internally, so activation memory is
    bounded by the microbatch while optimizer semantics see the global batch.

    With ``tc.use_fused_lamb`` (and no explicit ``optimizer``), the step
    bypasses the transform chain entirely and calls the fused LAMB apply
    in-place on the fp32 masters — no parameter-delta round-trip.

    ``step_fn`` is mesh-agnostic: under a sharded launch the Trainer jits it
    with explicit ``in_shardings``/``out_shardings`` (see
    ``sharding.train_state_shardings``), and ``param_specs`` carries the
    parameter PartitionSpecs into the fused-LAMB per-leaf backend choice.
    """
    fused_direct = (
        optimizer is None and tc.optimizer == "lamb" and _wants_fused(model, tc)
    )
    loss_fn = make_loss_fn(model)  # cast hoisted into step_fn, see below
    n_micro = tc.grad_accum_steps
    compute_dtype = tc.compute_dtype

    def cast_params(params):
        if compute_dtype is None:
            return params
        return nn.cast_tree(params, jnp.dtype(compute_dtype))

    guard = tc.skip_nonfinite

    def grads_and_metrics(params, batch):
        # fault channels (tests/harness) ride the batch as fault/* leaves;
        # pop them before the loss sees the batch, apply to the grads after
        # accumulation — so a poisoned gradient looks exactly like a real
        # non-finite microbatch to the guard below
        batch, faults = split_faults(batch)
        grads, metrics = _microbatch_grads(
            loss_fn, cast_params(params), batch, n_micro
        )
        grads = apply_grad_faults(grads, faults)
        metrics = apply_loss_faults(dict(metrics), faults)
        metrics["grad_norm"] = _global_norm(grads)
        return grads, metrics

    def finite_guard(grads, metrics):
        """Scalar ok-flag: everything the update would consume is finite."""
        return tree_all_finite(grads, metrics.get(LOSS_KEY))

    def trust_diag(params, updates):
        return core.summarize_trust_ratios(
            core.trust_ratio_tree(
                params, updates, layer_axes=model.layer_axes(),
                phi_bounds=tc.phi_bounds,
            )
        )

    # per-layer telemetry recording (off by default): the records stay on
    # device inside the metrics pytree — no host sync until the Trainer's
    # log-step fetch pops PER_LAYER_KEY
    record = tc.record_trust_ratios

    def per_layer_records(params, updates, applied_ratio=None):
        return core.trust_records(
            params, updates, layer_axes=model.layer_axes(),
            phi_bounds=tc.phi_bounds, trust_ratio=applied_ratio,
        )

    if fused_direct:
        _check_fused_supported(tc)
        fused_step = make_fused_lamb_step(
            schedule if schedule is not None else tc.learning_rate,
            tc.b1, tc.b2, tc.eps, tc.weight_decay,
            wd_mask=model.wd_mask(), trust_mask=model.trust_mask(),
            layer_axes=model.layer_axes(), phi_bounds=tc.phi_bounds,
            grad_clip_norm=tc.grad_clip_norm,
            mode=resolve_fused_backend(tc.fused_backend),
            param_specs=param_specs,
            with_aux=record,
        )

        def init_fn(rng) -> TrainState:
            params = model.init(rng)
            return TrainState(
                params, fused_lamb_init(params), jnp.zeros([], jnp.int32),
                jnp.zeros([], jnp.int32),
            )

        def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
            grads, metrics = grads_and_metrics(state.params, batch)
            if guard:
                # the guard threads through the fused apply: every leaf
                # where-selects old vs new in the same fused expression and
                # the moment/schedule counters advance by ok, so a skipped
                # step leaves the entire opt state bit-identical
                ok = finite_guard(grads, metrics)
                out = fused_step(state.params, grads, state.opt_state, ok=ok)
            else:
                out = fused_step(state.params, grads, state.opt_state)
            params, opt_state = out[0], out[1]
            # same metric schema as the unfused path; the subtraction fuses
            # into the norm reduction (no materialized delta tree)
            metrics["update_norm"] = _delta_norm(params, state.params)
            if tc.log_trust_ratios or record:
                updates = jax.tree.map(
                    lambda new, old: new.astype(jnp.float32)
                    - old.astype(jnp.float32),
                    params, state.params,
                )
                if tc.log_trust_ratios:
                    metrics.update(trust_diag(state.params, updates))
                if record:
                    # out[2] = the kernels' applied per-layer ratios (aux)
                    metrics[PER_LAYER_KEY] = per_layer_records(
                        state.params, updates, applied_ratio=out[2]
                    )
            if guard:
                adv = ok.astype(jnp.int32)
                metrics[GUARD_KEY] = 1.0 - adv.astype(jnp.float32)
                new_state = TrainState(
                    params, opt_state, state.step + adv,
                    state.skipped + (1 - adv),
                )
            else:
                new_state = TrainState(
                    params, opt_state, state.step + 1, state.skipped
                )
            return new_state, metrics

        return init_fn, step_fn

    opt = (
        optimizer
        if optimizer is not None
        else make_optimizer(model, tc, schedule, param_specs=param_specs)
    )

    def init_fn(rng) -> TrainState:
        params = model.init(rng)
        return TrainState(params, opt.init(params), jnp.zeros([], jnp.int32),
                          jnp.zeros([], jnp.int32))

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = grads_and_metrics(state.params, batch)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        if guard:
            # tree.map(where) select at the TrainState level: a non-finite
            # step passes params AND the whole transform-chain state through
            # unchanged — schedule counters included, since ScheduleState
            # lives inside opt_state
            ok = finite_guard(grads, metrics)
            keep = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
            params = jax.tree.map(keep, params, state.params)
            opt_state = jax.tree.map(keep, opt_state, state.opt_state)
            adv = ok.astype(jnp.int32)
            metrics["update_norm"] = jnp.where(ok, _global_norm(updates), 0.0)
            metrics[GUARD_KEY] = 1.0 - adv.astype(jnp.float32)
        else:
            metrics["update_norm"] = _global_norm(updates)
        if tc.log_trust_ratios:
            metrics.update(trust_diag(state.params, updates))
        if record:
            # transform chains don't expose their internal ratio; record the
            # post-hoc phi(||x||)/||Δx|| diagnostic (same semantics as
            # trust_diag, per layer instead of summarized)
            metrics[PER_LAYER_KEY] = per_layer_records(state.params, updates)
        if guard:
            new_state = TrainState(params, opt_state, state.step + adv,
                                   state.skipped + (1 - adv))
        else:
            new_state = TrainState(params, opt_state, state.step + 1,
                                   state.skipped)
        return new_state, metrics

    return init_fn, step_fn


def _global_norm(tree):
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def _delta_norm(new_tree, old_tree):
    """Global L2 norm of (new - old) without materializing the delta tree."""
    sq = [
        jnp.sum(jnp.square(n.astype(jnp.float32) - o.astype(jnp.float32)))
        for n, o in zip(jax.tree.leaves(new_tree), jax.tree.leaves(old_tree))
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))
