"""SIGTERM/SIGINT preemption: flag-only handler + grace-window final save.

Cluster schedulers (and Ctrl-C) preempt with SIGTERM and a grace period.
The handler here does nothing signal-unsafe — it sets a flag the Trainer's
step loop polls between steps, so the in-flight jit'd step completes and
the final checkpoint is a *consistent* full TrainState, written through
the existing :class:`~repro.checkpoint.async_io.AsyncCheckpointer` and
drained with the grace-window timeout.  A second delivery of the same
signal stops absorbing and raises ``KeyboardInterrupt`` — the escape hatch
when the grace save itself hangs.

Handlers can only be installed from the main thread; elsewhere (e.g. a
Trainer driven from a worker thread) the context manager degrades to a
never-triggered no-op rather than failing.
"""
from __future__ import annotations

import signal
from typing import Dict, Optional, Tuple


class PreemptionHandler:
    """Context manager: install flag-setting handlers, restore on exit."""

    def __init__(self, enabled: bool = True,
                 signals: Tuple[int, ...] = (signal.SIGTERM, signal.SIGINT)):
        self.enabled = enabled
        self.signals = tuple(signals)
        self.triggered = False
        self.signum: Optional[int] = None
        self._old: Dict[int, object] = {}

    def _on_signal(self, signum, frame) -> None:
        if self.triggered:
            raise KeyboardInterrupt(
                f"second {signal.Signals(signum).name} during preemption "
                "grace window"
            )
        self.triggered = True
        self.signum = signum

    @property
    def signal_name(self) -> str:
        return signal.Signals(self.signum).name if self.signum else "none"

    def __enter__(self) -> "PreemptionHandler":
        if not self.enabled:
            return self
        try:
            for s in self.signals:
                self._old[s] = signal.signal(s, self._on_signal)
        except ValueError:
            # not the main thread: signal.signal refuses; run unprotected
            for s, old in self._old.items():
                signal.signal(s, old)
            self._old.clear()
        return self

    def __exit__(self, *exc) -> None:
        for s, old in self._old.items():
            signal.signal(s, old)
        self._old.clear()
