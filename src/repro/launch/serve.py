"""Serving launcher: static-batch or continuous-batching generation.

    # static batch (pad everything to one shape, block until done)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 8 --prompt-len 16 --max-new 32

    # continuous batching over a slot pool with Poisson arrivals
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --continuous --slots 4 --arrival-rate 8 --requests 16

``--telemetry-dir`` (continuous mode) writes a structured event log — one
``serve_request`` event per request lifecycle (TTFT, latency, drops) plus a
``serve_stats`` aggregate with queue-depth and slot-occupancy counters — and
a ``RUN_REPORT.json`` rollup at exit.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.telemetry import EventLog, RunReport, run_provenance
from repro.serve import (
    ContinuousEngine,
    Engine,
    FCFSScheduler,
    Request,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    serving_stats,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a KV slot pool")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous mode)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request rate in req/s (0 = all at once)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="admission deadline in seconds (continuous mode)")
    ap.add_argument("--max-prefills-per-step", type=int, default=2)
    ap.add_argument("--telemetry-dir", default="",
                    help="write events.jsonl + RUN_REPORT.json here "
                         "(continuous mode; off = null sink)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode/serve path")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.max_new + 8
    prompts = [
        rng.integers(0, min(cfg.vocab_size, 1024),
                     size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]

    if args.continuous:
        telemetry = (EventLog.to_dir(args.telemetry_dir)
                     if args.telemetry_dir else EventLog())
        if telemetry.enabled:
            telemetry.emit("run_start", mode="serve", arch=cfg.name,
                           n_slots=args.slots,
                           arrival_rate=args.arrival_rate,
                           provenance=run_provenance(configs=(cfg,)))
        eng = ContinuousEngine(
            model, params, n_slots=args.slots, max_len=max_len,
            seed=args.seed,
            scheduler=FCFSScheduler(args.max_prefills_per_step),
            telemetry=telemetry,
        )
        reqs = [
            ServeRequest(p, max_new_tokens=args.max_new,
                         temperature=args.temperature,
                         deadline_s=args.deadline)
            for p in prompts
        ]
        assign_arrivals(
            reqs, poisson_arrivals(len(reqs), args.arrival_rate,
                                   seed=args.seed))
        out = eng.generate(reqs)
        for i, r in enumerate(out[:4]):
            print(f"req[{i}] (+{r.arrival_s:.3f}s) -> "
                  f"{np.asarray(r.out_tokens[:16])}...")
        print(f"stats: {serving_stats(out)}")
        if telemetry.enabled:
            telemetry.emit("run_end", status="ok")
            report_path = Path(args.telemetry_dir) / "RUN_REPORT.json"
            RunReport.from_events(telemetry.path).write(report_path)
            print(f"telemetry: {telemetry.path} report: {report_path}")
        return

    eng = Engine(model, params, max_len=max_len, seed=args.seed)
    reqs = [
        Request(prompt=p, max_new_tokens=args.max_new,
                temperature=args.temperature)
        for p in prompts
    ]
    out = eng.generate_batch(reqs)
    stats = eng.throughput_stats(out)
    for i, r in enumerate(out[:4]):
        print(f"req[{i}] -> {r.out_tokens[:16]}...")
    print(f"stats: {stats}")


if __name__ == "__main__":
    main()
