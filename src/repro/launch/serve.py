"""Serving launcher: static-batch or continuous-batching generation.

    # static batch (pad everything to one shape, block until done)
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 8 --prompt-len 16 --max-new 32

    # continuous batching over a slot pool with Poisson arrivals
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --continuous --slots 4 --arrival-rate 8 --requests 16

``--telemetry-dir`` (continuous mode) writes a structured event log — one
``serve_request`` event per request lifecycle (TTFT, latency, terminal
status) plus the reliability lifecycle events (shed/timeout/retry/
quarantine/degrade/drain) and a ``serve_stats`` aggregate — and a
``RUN_REPORT.json`` rollup at exit.

Reliability flags (continuous mode): ``--max-queue``/``--max-queue-tokens``
bound the arrived backlog (admission control), ``--timeout`` caps each
request's total latency, ``--stall-slo`` arms the stall watchdog,
``--retries`` bounds transient-failure retries, ``--inject-faults`` takes a
deterministic fault list (``kind@ordinal[:persist][:stall=S]``, see
``serve/faults.py``), and SIGTERM/SIGINT trigger a graceful drain: no new
admissions, in-flight work finishes within ``--drain-grace`` seconds, the
rest is shed, and the process exits with a clean terminal-state summary.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.telemetry import EventLog, RunReport, run_provenance
from repro.train.preempt import PreemptionHandler
from repro.serve import (
    ContinuousEngine,
    Engine,
    FCFSScheduler,
    Request,
    ServeFaultInjector,
    ServeRequest,
    assign_arrivals,
    parse_fault_specs,
    poisson_arrivals,
    serving_stats,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a KV slot pool")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (continuous mode)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson request rate in req/s (0 = all at once)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="admission deadline in seconds (continuous mode)")
    ap.add_argument("--max-prefills-per-step", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-request total latency budget in seconds")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the arrived backlog (requests); overload "
                         "beyond this is shed, not queued")
    ap.add_argument("--max-queue-tokens", type=int, default=None,
                    help="bound the arrived backlog by estimated "
                         "prompt+generation tokens")
    ap.add_argument("--stall-slo", type=float, default=None,
                    help="per-decode-step SLO in seconds; a step past it "
                         "degrades admissions until recovery")
    ap.add_argument("--retries", type=int, default=2,
                    help="transient-failure retry budget per request")
    ap.add_argument("--inject-faults", default="",
                    help="deterministic fault list, e.g. "
                         "'sample_nan@1,slot_corrupt@2:persist,"
                         "decode_stall@3:stall=0.2'")
    ap.add_argument("--drain-grace", type=float, default=5.0,
                    help="seconds in-flight requests get to finish after "
                         "SIGTERM/SIGINT before being shed")
    ap.add_argument("--telemetry-dir", default="",
                    help="write events.jsonl + RUN_REPORT.json here "
                         "(continuous mode; off = null sink)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode/serve path")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.max_new + 8
    prompts = [
        rng.integers(0, min(cfg.vocab_size, 1024),
                     size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]

    if args.continuous:
        telemetry = (EventLog.to_dir(args.telemetry_dir)
                     if args.telemetry_dir else EventLog())
        if telemetry.enabled:
            telemetry.emit("run_start", mode="serve", arch=cfg.name,
                           n_slots=args.slots,
                           arrival_rate=args.arrival_rate,
                           provenance=run_provenance(configs=(cfg,)))
        faults = (ServeFaultInjector(parse_fault_specs(args.inject_faults))
                  if args.inject_faults else None)
        eng = ContinuousEngine(
            model, params, n_slots=args.slots, max_len=max_len,
            seed=args.seed,
            scheduler=FCFSScheduler(args.max_prefills_per_step,
                                    max_queue=args.max_queue,
                                    max_queue_tokens=args.max_queue_tokens),
            telemetry=telemetry,
            faults=faults,
            max_retries=args.retries,
            stall_slo_s=args.stall_slo,
        )
        reqs = [
            ServeRequest(p, max_new_tokens=args.max_new,
                         temperature=args.temperature,
                         deadline_s=args.deadline,
                         timeout_s=args.timeout)
            for p in prompts
        ]
        assign_arrivals(
            reqs, poisson_arrivals(len(reqs), args.arrival_rate,
                                   seed=args.seed))
        # graceful drain: SIGTERM/SIGINT flips a flag the generate loop
        # polls — admissions stop, in-flight work gets --drain-grace
        with PreemptionHandler() as preempt:
            out = eng.generate(
                reqs,
                should_drain=lambda: preempt.triggered,
                drain_grace_s=args.drain_grace,
            )
        for i, r in enumerate(out[:4]):
            print(f"req[{i}] (+{r.arrival_s:.3f}s) [{r.status.value}] -> "
                  f"{np.asarray(r.out_tokens[:16])}...")
        stats = serving_stats(out)
        print(f"stats: {stats}")
        summary = " ".join(
            f"{k}={stats.get(k, 0)}"
            for k in ("submitted", "completed", "shed", "timed_out", "failed"))
        if preempt.triggered:
            print(f"drained ({preempt.signal_name}): {summary}")
        else:
            print(f"done: {summary}")
        if faults is not None:
            print(f"faults fired: {faults.fire_counts()}")
        if telemetry.enabled:
            telemetry.emit(
                "run_end",
                status="drained" if preempt.triggered else "ok")
            report_path = Path(args.telemetry_dir) / "RUN_REPORT.json"
            RunReport.from_events(telemetry.path).write(report_path)
            print(f"telemetry: {telemetry.path} report: {report_path}")
        return

    eng = Engine(model, params, max_len=max_len, seed=args.seed)
    reqs = [
        Request(prompt=p, max_new_tokens=args.max_new,
                temperature=args.temperature)
        for p in prompts
    ]
    out = eng.generate_batch(reqs)
    stats = eng.throughput_stats(out)
    for i, r in enumerate(out[:4]):
        print(f"req[{i}] -> {r.out_tokens[:16]}...")
    print(f"stats: {stats}")


if __name__ == "__main__":
    main()
