"""Serving launcher: batched greedy generation on a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --requests 8 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.serve import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode/serve path")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    eng = Engine(model, params,
                 max_len=args.prompt_len + args.max_new + 8, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            prompt=rng.integers(0, min(cfg.vocab_size, 1024),
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
        )
        for _ in range(args.requests)
    ]
    out = eng.generate_batch(reqs)
    stats = eng.throughput_stats(out)
    for i, r in enumerate(out[:4]):
        print(f"req[{i}] -> {r.out_tokens[:16]}...")
    print(f"stats: {stats}")


if __name__ == "__main__":
    main()
