"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs / peak_FLOPs_per_chip          [s]
    memory     = HLO_bytes / HBM_bw_per_chip              [s]
    collective = collective_bytes / link_bw_per_chip      [s]

``compiled.cost_analysis()`` is already *per-device* after SPMD partitioning,
so the per-chip peak constants divide directly (no extra /chips).
collective_bytes is parsed from the post-partitioning HLO text: the sum of
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (also per-device).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Matches "<name> = <result-shapes> <collective>(" including async "-start"
# forms; "-done" ops are deliberately NOT matched (they would double count).
# Compiled HLO prints operands by %name only, so bytes are derived from the
# RESULT shape + the replica group size, per collective kind:
#   all-reduce:         operand == result
#   all-gather:         operand == result / group_size
#   reduce-scatter:     operand == result * group_size
#   all-to-all:         operand == result
#   collective-permute: operand == result
_LINE_RE = re.compile(
    r"=\s*(.*?)\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form: replica_groups=[n_groups,group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit list: count members of the first group
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes, parsed from (partitioned) HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if m is None:
            continue
        kind = m.group(2)
        result_bytes = sum(
            _nbytes(dt, dims) for dt, dims in _SHAPE_RE.findall(m.group(1))
        )
        g = _group_size(line)
        if kind == "all-gather":
            nbytes = result_bytes // g
        elif kind == "reduce-scatter":
            nbytes = result_bytes * g
        else:
            nbytes = result_bytes
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_fraction: float
    collectives: Dict[str, int]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d

    def summary(self) -> str:
        return (
            f"compute {self.compute_s*1e3:9.3f} ms | memory {self.memory_s*1e3:9.3f} ms"
            f" | collective {self.collective_s*1e3:9.3f} ms → {self.dominant}-bound"
            f" | useful-FLOP frac {self.useful_fraction:6.3f}"
        )


def analyze(
    cost: dict,
    hlo_text: str,
    *,
    model_flops_per_device: float,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = collective_bytes(hlo_text)
    cb = float(colls["total"])
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = cb / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=cb,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        model_flops=model_flops_per_device,
        useful_fraction=(model_flops_per_device / flops) if flops else 0.0,
        collectives=colls,
    )


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D for inference fwd."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
