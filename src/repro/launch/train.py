"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 16 --seq 128 --optimizer lamb [--smoke] \
        [--mixed-batch] [--checkpoint-dir ckpt/] [--checkpoint-every 50] \
        [--async-checkpoint] [--resume] [--mesh data=8,model=1] \
        [--accum-steps 4] [--precision bf16] [--fused-lamb] [--fused-ce] \
        [--telemetry-dir runs/x] [--log-trust-ratios] \
        [--skip-nonfinite] [--rollback-on-spike --spike-window 32 \
         --max-rollbacks 3] [--preempt-grace 30]

``--checkpoint-dir`` + ``--checkpoint-every`` persist the full train state
(params, LAMB moments, step).  ``--async-checkpoint`` makes saves
double-buffered and non-blocking (disk writes overlap training;
``checkpoint`` telemetry events carry the timings), and ``--resume``
continues a killed run from the latest complete checkpoint — bit-exact
against a run that was never interrupted (docs/reliability.md).

``--telemetry-dir`` turns on the unified telemetry subsystem: a structured
JSONL event log (run provenance, per-interval step events, span timings,
checkpoints) plus a ``RUN_REPORT.json`` aggregate written at exit.  Combined
with ``--log-trust-ratios`` it also records LAMB's per-layer trust ratios
and update/param norms each logged step (App. H-style diagnostics).  Without
the flag every telemetry hook is a null sink — the step function and metrics
history are bit-identical to a run without telemetry.

``--fused-ce`` (default on for bert-large) runs the MLM head fused:
supervised positions are gathered before the vocab projection and the CE
streams over vocab chunks, so the ``(B, S, V)`` logits tensor never
exists (``--no-fused-ce`` restores the dense head).

``--batch`` is the *global* batch; ``--accum-steps k`` runs it as k
sequential microbatches of ``batch/k`` (activation memory scales with the
microbatch, optimizer semantics with the global batch — the paper's
batch-to-the-hardware-limit recipe on fixed memory).  ``--precision bf16``
computes forward/backward in bf16 against fp32 master params, and
``--fused-lamb`` routes the optimizer through the fused update kernel
(Pallas on TPU, fused XLA elsewhere).

``--mesh data=N,model=M`` runs the step truly sharded: params and LAMB
moments FSDP-sharded over ``data`` (TP over ``model``), batches split over
``data``, explicit in/out shardings on the jit'd step (see
docs/sharding.md).  With no ``--mesh``, multi-device hosts default to
``data=<all devices>`` (``--model-parallel`` is the legacy spelling for
the model axis).

Robustness (docs/reliability.md): ``--skip-nonfinite`` arms the in-jit
non-finite guard (NaN/Inf in loss or grads skips the update in-graph);
``--rollback-on-spike`` arms the loss-spike watchdog, which restores the
last *validated* checkpoint on a trip and aborts with exit code 3 after
``--max-rollbacks``; ``--preempt-grace N`` turns SIGTERM/SIGINT into a
final checkpoint + clean ``status=preempted`` exit, resumable bit-exact
with ``--resume``.

``--optimizer`` picks the update rule: ``lamb`` (Algorithm 2, default),
``lans`` (Zheng et al.'s 54-minute variant — block-normalized gradients
into the Adam moments plus a Nesterov two-term update, each term
trust-rescaled per layer; see core/lans.py), ``nlamb``/``nnlamb`` (App. D),
``lars``, and the tuned baselines ``adam``/``adamw``/``adagrad``/
``momentum``.  All of them run through the same accumulation / precision /
sharding path; ``--fused-lamb`` applies to LAMB only.

``--smoke`` swaps in the reduced config of the same family (CPU-runnable);
the full configs are exercised via the dry-run (repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

import jax

from repro import core
from repro.configs import get_config, smoke_config
from repro.configs.base import TrainConfig
from repro.core.mixed_batch import make_stage
from repro.data import DataPipeline
from repro.launch.mesh import make_host_mesh, make_mesh_from_spec
from repro.models import build_model
from repro.telemetry import EventLog, RunReport
from repro.train import DivergenceError, SupervisorConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--base-lr", type=float, default=2.5e-3)
    ap.add_argument("--base-batch", type=int, default=16)
    ap.add_argument("--warmup-ratio", type=float, default=1 / 40)
    ap.add_argument("--weight-decay", type=float, default=0.01)
    ap.add_argument("--mixed-batch", action="store_true",
                    help="two-stage §4.1 recipe (seq -> 4*seq, batch -> batch/4)")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--precision", default="fp32", choices=["fp32", "bf16"],
                    help="compute dtype (bf16 keeps fp32 master params)")
    ap.add_argument("--fused-lamb", action="store_true",
                    help="fused LAMB update (Pallas on TPU, XLA fallback)")
    ap.add_argument("--flash", dest="flash", action="store_true", default=None,
                    help="force flash attention on (Pallas fwd+bwd kernels "
                         "on TPU, chunked XLA elsewhere)")
    ap.add_argument("--no-flash", dest="flash", action="store_false",
                    help="force the dense attention path")
    ap.add_argument("--fused-ce", dest="fused_ce", action="store_true",
                    default=None,
                    help="force the fused MLM head on (supervised-position "
                         "gather + chunked-vocab CE; no (B,S,V) logits — "
                         "default on for bert-large)")
    ap.add_argument("--no-fused-ce", dest="fused_ce", action="store_false",
                    help="force the dense logits + log_softmax head")
    ap.add_argument("--log-trust-ratios", action="store_true",
                    help="per-step trust-ratio min/mean/max in history; with "
                         "--telemetry-dir, also the per-layer recorder "
                         "(trust_ratios events + histogram in the report)")
    ap.add_argument("--telemetry-dir", default="",
                    help="write a structured event log (events.jsonl) and a "
                         "RUN_REPORT.json aggregate here; off = null sink "
                         "(zero overhead)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--async-checkpoint", action="store_true",
                    help="double-buffered background saves: the step loop "
                         "pays only the device->host snapshot, the disk "
                         "write overlaps training (checkpoint telemetry "
                         "events carry snapshot/blocked/write timings)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest complete checkpoint in "
                         "--checkpoint-dir (full train state: params, "
                         "optimizer moments, step) and continue to --steps; "
                         "the data pipeline is fast-forwarded so the "
                         "continuation matches an uninterrupted run")
    ap.add_argument("--skip-nonfinite", action="store_true",
                    help="in-jit non-finite guard: any NaN/Inf in the loss "
                         "or gradients skips the optimizer update (state "
                         "passes through unchanged, schedule counters hold) "
                         "and counts the step in TrainState.skipped")
    ap.add_argument("--rollback-on-spike", action="store_true",
                    help="loss-spike watchdog: robust (median+MAD) z-score "
                         "over a trailing window; a trip restores the last "
                         "validated checkpoint and fast-forwards the data "
                         "stream past the suspect batches (requires "
                         "--checkpoint-dir + --checkpoint-every)")
    ap.add_argument("--spike-window", type=int, default=32,
                    help="trailing-loss window size for the spike detector")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="rollback budget; exceeding it aborts with a "
                         "divergence diagnostic (exit code 3)")
    ap.add_argument("--preempt-grace", type=float, default=None,
                    help="seconds: install a SIGTERM/SIGINT handler that "
                         "finishes the current step, writes a final "
                         "checkpoint (bounded by this grace window) and "
                         "exits cleanly with status=preempted")
    ap.add_argument("--mesh", default="",
                    help="mesh axes, e.g. data=8,model=1 (uses the first "
                         "prod(sizes) local devices); params + LAMB moments "
                         "are FSDP-sharded over data, TP over model")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="legacy spelling: model-axis size of the host mesh "
                         "(ignored when --mesh is given)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.accum_steps < 1:
        raise SystemExit(f"--accum-steps must be >= 1, got {args.accum_steps}")
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    if args.rollback_on_spike and not (
        args.checkpoint_dir and args.checkpoint_every
    ):
        raise SystemExit(
            "--rollback-on-spike requires --checkpoint-dir and "
            "--checkpoint-every (rollback needs a checkpoint to restore)"
        )
    if args.rollback_on_spike and args.mixed_batch:
        raise SystemExit("--rollback-on-spike is not supported with "
                         "--mixed-batch")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.flash is not None:
        cfg = cfg.replace(use_flash_kernel=args.flash)
    if args.fused_ce is not None:
        cfg = cfg.replace(use_fused_ce_head=args.fused_ce)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M "
          f"active={model.active_param_count()/1e6:.1f}M")
    print(f"global_batch={args.batch} "
          f"microbatch={args.batch // args.accum_steps} "
          f"accum={args.accum_steps} precision={args.precision} "
          f"fused_lamb={args.fused_lamb} flash={cfg.use_flash_kernel} "
          f"fused_ce={cfg.use_fused_ce_head}")

    mesh = None
    if args.mesh:
        mesh = make_mesh_from_spec(args.mesh)
    elif args.model_parallel > 1 or len(jax.devices()) > 1:
        mesh = make_host_mesh(args.model_parallel)
    if mesh is not None:
        print(f"mesh={dict(mesh.shape)} devices={mesh.devices.size}")

    lr = core.sqrt_scaled_lr(args.base_lr, args.base_batch, args.batch)
    warmup_ratio = core.linear_epoch_warmup_ratio(
        args.warmup_ratio, args.base_batch, args.batch
    )
    if args.batch % args.accum_steps:
        raise SystemExit(
            f"--batch {args.batch} must be divisible by --accum-steps "
            f"{args.accum_steps}"
        )
    telemetry = (EventLog.to_dir(args.telemetry_dir) if args.telemetry_dir
                 else EventLog())
    tc = TrainConfig(
        optimizer=args.optimizer, learning_rate=lr,
        weight_decay=args.weight_decay, total_steps=args.steps, seed=args.seed,
        accum_steps=args.accum_steps, precision=args.precision,
        use_fused_lamb=args.fused_lamb,
        skip_nonfinite=args.skip_nonfinite,
        log_trust_ratios=args.log_trust_ratios,
        # per-layer recording costs a host transfer per logged step — only
        # worth it when there is an event log to receive it
        record_trust_ratios=args.log_trust_ratios and telemetry.enabled,
    )
    trainer = Trainer(
        model, tc,
        schedule=core.warmup_poly_decay(
            lr, args.steps, int(args.steps * warmup_ratio)),
        mesh=mesh,
        checkpoint_dir=args.checkpoint_dir or None,
        checkpoint_every=args.checkpoint_every,
        async_checkpoint=args.async_checkpoint,
        resume=args.resume,
        log_every=args.log_every,
        telemetry=telemetry,
        supervisor=(
            SupervisorConfig(spike_window=args.spike_window,
                             max_rollbacks=args.max_rollbacks)
            if args.rollback_on_spike else None
        ),
        preempt_grace=args.preempt_grace,
    )

    if args.mixed_batch:
        stages = [
            make_stage("stage1", args.seq, args.batch,
                       int(args.steps * 0.8), base_lr=args.base_lr,
                       base_batch=args.base_batch,
                       base_warmup_ratio=args.warmup_ratio),
            make_stage("stage2_rewarmup", args.seq * 4, max(args.batch // 4, 1),
                       args.steps - int(args.steps * 0.8),
                       base_lr=args.base_lr, base_batch=args.base_batch,
                       base_warmup_ratio=args.warmup_ratio),
        ]
        # every stage batch must slice into accum_steps microbatches AND
        # split over the mesh's data axes, else stage 2 would crash after
        # stage 1 already trained
        from repro.sharding import dp_size

        dp = 1 if mesh is None else dp_size(mesh)
        for st in stages:
            if st.batch_size % args.accum_steps:
                raise SystemExit(
                    f"stage {st.name!r} batch {st.batch_size} is not "
                    f"divisible by --accum-steps {args.accum_steps}"
                )
            if st.batch_size % dp:
                raise SystemExit(
                    f"stage {st.name!r} batch {st.batch_size} is not "
                    f"divisible by the mesh's data-parallel size {dp}"
                )
    # the Trainer emits run_end (with status) from a finally, so the report
    # is written even when the run aborts — a diverged run's RUN_REPORT is
    # exactly the diagnostic artifact you want to inspect
    exit_code = 0
    try:
        if args.mixed_batch:
            trainer.fit_stages(stages, data_seed=args.seed)
        else:
            def make_data():
                return DataPipeline(cfg, args.batch, args.seq,
                                    seed=args.seed, mesh=mesh)

            trainer.fit(make_data(), args.steps, data_factory=make_data)
    except DivergenceError as e:
        print(f"DIVERGED: {e}", file=sys.stderr)
        for k, v in e.diagnostics.items():
            print(f"  {k}: {v}", file=sys.stderr)
        exit_code = 3
    finally:
        if telemetry.enabled:
            report_path = Path(args.telemetry_dir) / "RUN_REPORT.json"
            RunReport.from_events(telemetry.path).write(report_path)
            print(f"telemetry: {telemetry.path} report: {report_path}")

    final = trainer.history[-1] if trainer.history else {}
    loss = final.get("loss/total")
    print(f"done: step={final.get('step')} "
          f"loss={'n/a' if loss is None else f'{loss:.4f}'} "
          f"acc={final.get('accuracy', 0.0):.4f} status={trainer._status}")
    if exit_code:
        sys.exit(exit_code)


if __name__ == "__main__":
    main()
