"""Production mesh definitions (TPU v5e).

single pod : (data=16, model=16)           = 256 chips
multi-pod  : (pod=2, data=16, model=16)    = 512 chips

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across API generations: newer releases expose
    jax.sharding.AxisType and expect explicit axis_types; jax 0.4.x has
    neither (all axes are implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))
