"""Mesh construction (production TPU v5e shapes + host meshes for tests).

single pod : (data=16, model=16)           = 256 chips
multi-pod  : (pod=2, data=16, model=16)    = 512 chips

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import numpy as np


def _make_mesh(shape, axes):
    """jax.make_mesh across API generations: newer releases expose
    jax.sharding.AxisType and expect explicit axis_types; jax 0.4.x has
    neither (all axes are implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """AbstractMesh across JAX API generations (no devices needed).

    Newer releases take ``(axis_sizes, axis_names)``; jax 0.4.x takes one
    ``((name, size), ...)`` tuple.  Abstract meshes carry only axis
    structure — enough for ``resolve_spec``/``specs_for`` — so sharding
    layouts can be planned on machines without the target device count.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """The paper-scale mesh: one or two TPU v5e pods (see module doc)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """(data, model) mesh over whatever devices exist (tests / CPU examples).

    All local devices participate; ``model_parallel`` of them form the
    ``model`` axis and the rest fan out over ``data``.
    """
    n = len(jax.devices())
    if model_parallel < 1 or n % model_parallel:
        raise ValueError(
            f"model_parallel={model_parallel} must be a positive divisor of "
            f"the device count ({n} available)"
        )
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """Parse a ``--mesh`` string like ``"data=4,model=2"`` into axis sizes.

    Axis order in the string is preserved (it becomes the mesh axis order);
    sizes must be positive integers.
    """
    out: Dict[str, int] = {}
    for item in spec.split(","):
        name, eq, val = item.strip().partition("=")
        if not eq or not name:
            raise ValueError(
                f"bad mesh axis {item!r} in {spec!r}; expected name=size"
            )
        try:
            size = int(val)
        except ValueError:
            raise ValueError(f"mesh axis {name!r} size {val!r} is not an int")
        if size < 1:
            raise ValueError(f"mesh axis {name!r} size must be >= 1, got {size}")
        if name in out:
            raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
        out[name] = size
    return out


def make_mesh_from_spec(spec: str):
    """Build a host mesh from a ``--mesh`` string (e.g. ``"data=8,model=1"``).

    Uses the first ``prod(sizes)`` local devices, so a subset mesh (fewer
    devices than available) is allowed; asking for more than exist raises a
    ``ValueError`` naming the device count.
    """
    axes = parse_mesh_spec(spec)
    names = tuple(axes)
    shape = tuple(axes.values())
    n_need = int(np.prod(shape))
    devices = jax.devices()
    if n_need > len(devices):
        raise ValueError(
            f"mesh {spec!r} needs {n_need} devices but only "
            f"{len(devices)} are available"
        )
    if n_need == len(devices):
        return _make_mesh(shape, names)
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n_need]).reshape(shape), names)
