import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Test hook only: REPRO_DRYRUN_DEVICES=8 shrinks the fake device pool (the
# production dry-run always uses the 512 set above).  Still before jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, with NO array allocation (ShapeDtypeStruct inputs), and
extract memory / cost / collective roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod] [--out results.jsonl] [--set remat=full]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, get_shape, plan  # noqa: E402
from repro.configs.base import InputShape, ModelConfig, TrainConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze, model_flops  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve.engine import make_decode_step, make_prefill_step  # noqa: E402
from repro.sharding import (  # noqa: E402
    ShardCtx,
    batch_shardings,
    cache_shardings,
    default_act_rules,
    opt_state_shardings,
    resolve_spec,
    shardings_for,
    use_sharding,
)
from repro.train.step import TrainState, make_optimizer, make_train_step  # noqa: E402


# Placement trees (batch_shardings / cache_shardings / opt_state_shardings)
# live in repro.sharding.placement — shared with the real Trainer path, so
# the layouts this dry-run compiles are the layouts training runs.

# ---------------------------------------------------------------------------
# step builders: (fn, abstract args, in_shardings, donate)
# ---------------------------------------------------------------------------

def build_train(model, shape: InputShape, mesh, rules, optimizer: str,
                param_rules=None, tc_kw=None):
    tc = TrainConfig(optimizer=optimizer, learning_rate=1e-3, **(tc_kw or {}))
    opt = make_optimizer(model, tc)
    _, step_fn = make_train_step(model, tc, optimizer=opt)

    aparams = model.abstract_params()
    aopt = jax.eval_shape(opt.init, aparams)
    counter = jax.ShapeDtypeStruct((), jnp.int32)
    astate = TrainState(aparams, aopt, counter, counter)
    abatch = model.input_specs(shape)

    psh = shardings_for(model.defs, mesh, param_rules)
    osh = opt_state_shardings(aopt, psh, mesh)
    ssh = TrainState(psh, osh, NamedSharding(mesh, P()),
                     NamedSharding(mesh, P()))
    bsh = batch_shardings(abatch, mesh, rules)

    def wrapped(state, batch):
        new_state, metrics = step_fn(state, batch)
        # keep the output state resident where the input state lives
        new_state = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), new_state, ssh
        )
        return new_state, metrics

    return wrapped, (astate, abatch), (ssh, bsh), (0,)


def build_prefill(model, shape: InputShape, mesh, rules, param_rules=None):
    fn = make_prefill_step(model)
    aparams = model.abstract_params()
    abatch = model.input_specs(shape)
    acache = model.make_cache(shape.global_batch, shape.seq_len, abstract=True)
    psh = shardings_for(model.defs, mesh, param_rules)
    bsh = batch_shardings(abatch, mesh, rules)
    csh = cache_shardings(acache, mesh, rules)
    return fn, (aparams, abatch, acache), (psh, bsh, csh), (2,)


def build_decode(model, shape: InputShape, mesh, rules, param_rules=None):
    fn = make_decode_step(model)
    b = shape.global_batch
    aparams = model.abstract_params()
    acache = model.make_cache(b, shape.seq_len, abstract=True)
    atok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    apos = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    psh = shardings_for(model.defs, mesh, param_rules)
    csh = cache_shardings(acache, mesh, rules)
    tsh = NamedSharding(mesh, resolve_spec((b, 1), ("batch", None), rules, mesh))
    return fn, (aparams, acache, atok, apos), (psh, csh, tsh, tsh), (1,)


def build_encoder_forward(model, shape: InputShape, mesh, rules):
    """Encoder 'prefill' = plain forward (no cache)."""

    def fn(params, batch):
        logits, _ = model.apply(params, batch)
        return logits[:, -1]

    aparams = model.abstract_params()
    abatch = model.input_specs(shape)
    psh = shardings_for(model.defs, mesh)
    bsh = batch_shardings(abatch, mesh, rules)
    return fn, (aparams, abatch), (psh, bsh), ()


# ---------------------------------------------------------------------------
# main runner
# ---------------------------------------------------------------------------

def _cost_dict(cost) -> Dict[str, float]:
    """cost_analysis() across JAX API generations: jax 0.4.x returns a
    one-element list of dicts, newer releases a plain dict."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost) if cost else {}


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    ):
        try:
            out[attr] = int(getattr(mem, attr))
        except (AttributeError, TypeError):
            pass
    return out


def apply_overrides(cfg: ModelConfig, sets) -> ModelConfig:
    for item in sets or []:
        key, _, val = item.partition("=")
        cur = getattr(cfg, key)
        if isinstance(cur, bool):
            parsed: Any = val.lower() in ("1", "true", "yes")
        elif cur is None:
            parsed = None if val.lower() == "none" else int(val)
        elif isinstance(cur, int):
            parsed = int(val)
        elif isinstance(cur, float):
            parsed = float(val)
        else:
            parsed = val
        cfg = cfg.replace(**{key: parsed})
    return cfg


def run_dryrun(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    optimizer: str = "lamb",
    sets=None,
    mesh=None,
    act_rule_sets=None,
    param_rule_sets=None,
    moment_dtype: Optional[str] = None,
    tag: str = "",
) -> Dict[str, Any]:
    shape = get_shape(shape_name)
    cfg0 = get_config(arch)
    cfg, note = plan(cfg0, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2pod" if multi_pod else "1pod",
        "optimizer": optimizer, "note": note, "tag": tag,
        "overrides": list(sets or []),
        "act_rules": list(act_rule_sets or []),
        "param_rules": list(param_rule_sets or []),
        "moment_dtype": moment_dtype,
    }
    if cfg is None:
        record["status"] = "skipped"
        return record
    cfg = apply_overrides(cfg, sets)

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = default_act_rules(multi_pod="pod" in mesh.shape)
    rules["cache_seq"] = ("pod", "data")
    rules["inner"] = ("model",)
    for item in act_rule_sets or []:
        k, _, v = item.partition("=")
        rules[k] = tuple(x for x in v.split(",") if x) or None

    param_rules = None
    if param_rule_sets:
        from repro.sharding import default_param_rules

        param_rules = default_param_rules(multi_pod="pod" in mesh.shape)
        for item in param_rule_sets:
            k, _, v = item.partition("=")
            param_rules[k] = tuple(x for x in v.split(",") if x) or None
    tc_kw = {"moment_dtype": moment_dtype} if moment_dtype else {}

    model = build_model(cfg)
    if shape.kind == "train":
        builder = lambda: build_train(model, shape, mesh, rules, optimizer,
                                      param_rules, tc_kw)
    elif shape.kind == "prefill":
        builder = (
            (lambda: build_encoder_forward(model, shape, mesh, rules))
            if cfg.is_encoder
            else (lambda: build_prefill(model, shape, mesh, rules, param_rules))
        )
    else:
        builder = lambda: build_decode(model, shape, mesh, rules, param_rules)

    ctx = ShardCtx(mesh, rules)
    t0 = time.perf_counter()
    with use_sharding(ctx):
        fn, args, in_sh, donate = builder()
        lowered = jax.jit(
            fn, in_shardings=in_sh, donate_argnums=donate
        ).lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = _cost_dict(compiled.cost_analysis())
    try:
        mem = _mem_dict(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    hlo = compiled.as_text()
    cost_source = "scanned"

    # XLA cost analysis counts while-loop (lax.scan) bodies ONCE regardless of
    # trip count, so FLOPs/bytes/collectives of scanned stacks are undercounted
    # by ~n_layers.  Re-lower the mathematically identical UNROLLED variant
    # purely for cost accounting (memory/compile stats above stay from the
    # production scanned artifact).
    if cfg.scan_layers and not os.environ.get("REPRO_DRYRUN_NO_UNROLL"):
        try:
            model_u = build_model(cfg.replace(scan_layers=False))
            with use_sharding(ctx):
                if shape.kind == "train":
                    fn_u, args_u, sh_u, dn_u = build_train(
                        model_u, shape, mesh, rules, optimizer,
                        param_rules, tc_kw)
                elif shape.kind == "prefill":
                    fn_u, args_u, sh_u, dn_u = (
                        build_encoder_forward(model_u, shape, mesh, rules)
                        if cfg.is_encoder
                        else build_prefill(model_u, shape, mesh, rules,
                                           param_rules)
                    )
                else:
                    fn_u, args_u, sh_u, dn_u = build_decode(
                        model_u, shape, mesh, rules, param_rules)
                compiled_u = jax.jit(
                    fn_u, in_shardings=sh_u, donate_argnums=dn_u
                ).lower(*args_u).compile()
            cost = _cost_dict(compiled_u.cost_analysis()) or cost
            hlo = compiled_u.as_text()
            cost_source = "unrolled"
        except Exception as e:  # pragma: no cover — fall back to scanned cost
            cost_source = f"scanned (unrolled failed: {type(e).__name__})"

    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mf = model_flops(shape.kind, model.active_param_count(), tokens) / n_dev
    rf = analyze(cost, hlo, model_flops_per_device=mf)

    record.update(
        status="ok",
        devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        params=model.param_count(),
        active_params=model.active_param_count(),
        tokens=tokens,
        memory=mem,
        cost={k: cost.get(k) for k in ("flops", "bytes accessed",
                                       "bytes accessed output") if k in cost},
        roofline=rf.to_dict(),
        cost_source=cost_source,
        hlo_lines=hlo.count("\n"),
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", default="lamb")
    ap.add_argument("--set", action="append", default=[],
                    help="model-config override key=value (repeatable)")
    ap.add_argument("--act-rule", action="append", default=[],
                    help="activation sharding rule override name=axis1,axis2")
    ap.add_argument("--param-rule", action="append", default=[],
                    help="parameter sharding rule override name=axis1,axis2 "
                         "(empty value replicates that logical axis)")
    ap.add_argument("--moment-dtype", default="",
                    help="optimizer moment dtype override (e.g. bfloat16)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    rec = run_dryrun(
        args.arch, args.shape, multi_pod=args.multi_pod,
        optimizer=args.optimizer, sets=args.set,
        act_rule_sets=args.act_rule, param_rule_sets=args.param_rule,
        moment_dtype=args.moment_dtype or None, tag=args.tag,
    )
    if rec.get("status") == "ok":
        rl = rec["roofline"]
        print(f"== {args.arch} × {args.shape} × {rec['mesh']} "
              f"[{rec['optimizer']}] ==")
        print(f"  lower {rec['lower_s']}s compile {rec['compile_s']}s  "
              f"hlo_lines {rec['hlo_lines']}")
        print(f"  memory_analysis: {json.dumps(rec['memory'])}")
        print(f"  cost_analysis:   {json.dumps(rec['cost'])}")
        print(f"  compute {rl['compute_s']*1e3:.3f}ms  memory "
              f"{rl['memory_s']*1e3:.3f}ms  collective "
              f"{rl['collective_s']*1e3:.3f}ms  → {rl['dominant']}-bound  "
              f"useful-FLOP {rl['useful_fraction']:.3f}")
    else:
        print(f"== {args.arch} × {args.shape}: {rec['status']} ({rec['note']})")
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
