"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) expert_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,            # unused (all layers MoE); kept to mirror the card
    vocab_size=49155,
    n_experts=32,
    n_experts_per_tok=8,
    moe_d_ff=512,
    tie_embeddings=True,
    act_fn="silu",
    norm_type="rmsnorm",
    use_rope=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="granite-moe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        vocab_size=512, n_experts=4, n_experts_per_tok=2, moe_d_ff=64, d_ff=64,
    )
