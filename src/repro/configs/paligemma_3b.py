"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216; SigLIP vision tower stubbed to 256 patch embeddings.
[arXiv:2407.07726]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,        # gemma-2b uses 256-dim heads
    d_ff=16384,
    vocab_size=257216,
    frontend="vision_stub",
    n_prefix_tokens=256,  # 224px / 14 SigLIP patches
    tie_embeddings=True,
    act_fn="gelu",
    norm_type="rmsnorm",
    use_rope=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="paligemma-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=512, n_prefix_tokens=4,
    )
