"""Config registry: ``get_config("deepseek-v3-671b")``, smoke variants, and
the (architecture × shape) applicability plan used by the dry-run."""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.configs.shapes import SHAPES, get_shape

_ARCH_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "paligemma-3b": "paligemma_3b",
    "granite-20b": "granite_20b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "hubert-xlarge": "hubert_xlarge",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "command-r-35b": "command_r_35b",
    "xlstm-350m": "xlstm_350m",
    "smollm-360m": "smollm_360m",
    "bert-large": "bert_large",
}

ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "bert-large"]


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()


# ---------------------------------------------------------------------------
# (arch × shape) plan
# ---------------------------------------------------------------------------

SWA_WINDOW_500K = 4096  # sliding-window variant used by dense archs on long_500k


def plan(cfg: ModelConfig, shape: InputShape) -> Tuple[Optional[ModelConfig], str]:
    """Returns (possibly-modified config, note).  config=None ⇒ skipped.

    Skips (recorded in DESIGN.md / EXPERIMENTS.md):
      * encoder-only archs have no decode step → decode shapes skipped;
      * full-attention archs run long_500k only via the sliding-window
        variant we implement (cfg.sliding_window := 4096).
    """
    if shape.kind == "decode" and cfg.is_encoder:
        return None, "skip: encoder-only (no decode step)"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or cfg.use_mla
        if not sub_quadratic and cfg.sliding_window is None:
            return (
                cfg.replace(sliding_window=SWA_WINDOW_500K),
                f"variant: sliding_window={SWA_WINDOW_500K} (full attention is "
                "not sub-quadratic; SWA variant per DESIGN.md)",
            )
    if shape.kind == "prefill" and cfg.is_encoder:
        return cfg, "encoder forward (no cache) stands in for prefill"
    return cfg, "ok"


def full_plan() -> Dict[Tuple[str, str], Tuple[Optional[ModelConfig], str]]:
    out = {}
    for arch in ARCHS:
        for sname, shape in SHAPES.items():
            out[(arch, sname)] = plan(get_config(arch), shape)
    return out


__all__ = [
    "ARCHS",
    "InputShape",
    "ModelConfig",
    "SHAPES",
    "TrainConfig",
    "full_plan",
    "get_config",
    "get_shape",
    "plan",
    "smoke_config",
]
