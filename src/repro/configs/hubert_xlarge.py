"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
(cluster codebook); encoder-only, masked frame-cluster prediction.  The conv
waveform frontend is stubbed: inputs are precomputed frame embeddings.
[arXiv:2106.07447]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,            # encoder-only: no decode shapes (see DESIGN.md)
    frontend="audio_stub",
    mask_ratio=0.08,
    act_fn="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    use_rope=False,          # conv positional embedding is part of the stub
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="hubert-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=64,
    )
