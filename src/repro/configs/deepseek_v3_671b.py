"""deepseek-v3-671b [moe] — 61L d_model=7168 128H MLA, expert_ff=2048
vocab=129280; 1 shared + 256 routed experts top-8, 3 leading dense layers
(d_ff=18432), optional MTP head.  [arXiv:2412.19437]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,       # MLA: all heads share the latent KV cache
    d_ff=18432,           # the 3 leading dense layers
    vocab_size=129280,
    n_dense_layers=3,
    n_experts=256,
    n_experts_per_tok=8,
    n_shared_experts=1,
    moe_d_ff=2048,        # assigned d_ff=2048 is the per-expert width
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    head_dim=192,         # qk_nope + qk_rope
    use_mtp=False,        # enabled in the MTP smoke test / ablation
    act_fn="silu",
    norm_type="rmsnorm",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v3-smoke", n_layers=3, n_dense_layers=1, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512, n_experts=4,
        n_experts_per_tok=2, moe_d_ff=64, q_lora_rank=64, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, head_dim=24,
    )
