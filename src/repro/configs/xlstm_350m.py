"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (no separate FFN — blocks carry their own projections).
[arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_ratio=2,          # (mLSTM, sLSTM) pairs
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    use_rope=False,
    norm_type="layernorm",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        vocab_size=512,
    )
