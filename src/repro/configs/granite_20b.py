"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152; llama-style port of the GPT-BigCode code model
(absolute positions → RoPE; recorded in DESIGN.md deviations).
[arXiv:2405.04324]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    act_fn="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    use_qkv_bias=True,
    use_rope=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="granite-20b-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        d_ff=512, vocab_size=512,
    )
