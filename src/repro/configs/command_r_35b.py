"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000; no biases, tied embeddings.  [hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    tie_embeddings=True,
    act_fn="silu",
    norm_type="layernorm",
    use_qkv_bias=False,
    rope_theta=8_000_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="command-r-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512,
    )
