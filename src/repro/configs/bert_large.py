"""bert-large — the paper's own training target (Devlin et al., 2018):
24L d_model=1024 16H d_ff=4096 vocab=30522, bidirectional encoder, MLM.
Used by the paper-claims benchmarks (LAMB vs Adam/LARS batch scaling).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bert-large",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    causal=False,          # bidirectional encoder; MLM loss
    mask_ratio=0.15,
    act_fn="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    use_rope=True,         # positional deviation from learned-absolute; see DESIGN.md
    tie_embeddings=True,
    use_flash_kernel=True,  # bidirectional flash attention fwd+bwd (Pallas on
                            # TPU, chunked-XLA elsewhere) — the train hot path
    use_fused_ce_head=True, # MLM head without the (B, S, V) logits: gather
                            # supervised positions, then chunked-vocab CE
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="bert-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512,
    )


def tiny(vocab: int = 2048) -> ModelConfig:
    """~10M-param BERT for CPU-scale paper-claims runs."""
    return CONFIG.replace(
        name="bert-tiny", n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=1024, vocab_size=vocab,
    )
