"""Model / training configuration dataclasses.

One ``ModelConfig`` describes any architecture in the zoo; family-specific
fields are simply unused elsewhere.  Configs are frozen dataclasses so they
are hashable (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default: d_model // n_heads

    # --- attention ---
    use_rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True            # False → encoder (hubert)
    sliding_window: Optional[int] = None
    use_qkv_bias: bool = False
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    act_fn: str = "silu"           # silu | gelu
    gated_mlp: bool = True
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1      # every k-th layer is MoE (1 = all)
    n_dense_layers: int = 0        # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 0.0

    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_absorb: bool = False       # beyond-paper decode optimization (§Perf)

    # --- hybrid / mamba (Jamba) ---
    mamba_chunk: Optional[int] = None  # chunked SSM scan (bounds temp memory)
    attn_period: int = 0           # 1 attention layer per `attn_period` layers
    moe_period_in_block: int = 2   # within a hybrid block, MoE every k layers
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: Optional[int] = None  # default ceil(d_model/16)

    # --- xLSTM ---
    slstm_ratio: int = 2           # 1 sLSTM per `slstm_ratio` layers (rest mLSTM)
    xlstm_proj_factor: float = 2.0

    # --- modality frontends (stubbed per assignment) ---
    n_prefix_tokens: int = 0       # image patches (vlm) / audio frames use seq directly
    frontend: str = "none"         # none | vision_stub | audio_stub
    mask_ratio: float = 0.0        # hubert masked-prediction ratio

    # --- MTP (DeepSeek-V3) ---
    use_mtp: bool = False
    mtp_loss_coef: float = 0.3

    # --- numerics / compile ---
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "none"            # none | full
    use_flash_kernel: bool = False # route attention through the Pallas kernel
    use_fused_lamb_kernel: bool = False
    use_fused_ce_head: bool = False  # fused MLM head: supervised-position
                                     # gather + chunked-vocab CE (no logits)
    fused_ce_backend: str = "auto"   # auto | pallas | xla | interpret
    mlm_max_predictions: Optional[int] = None  # fused-head gather buffer P;
                                     # default ceil(mask_ratio * seq_len)

    # --- optimizer interaction ---
    lamb_granularity: str = "slice"  # slice (per stacked layer) | leaf

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, "GQA group mismatch"

    def mlm_buffer_size(self, seq_len: int) -> int:
        """The fused-CE head's gather-buffer size P for this sequence length.

        ``mlm_max_predictions`` when set; otherwise ``ceil(mask_ratio · S)``
        (BERT's ``max_predictions_per_seq``), or S for unmasked objectives.
        This is the single source of truth for P: the loss sizes its gather
        buffer from it AND the synthetic MLM pipeline caps per-row target
        counts at it, so the two can never disagree.
        """
        if self.mlm_max_predictions is not None:
            return max(1, min(self.mlm_max_predictions, seq_len))
        if self.mask_ratio > 0:
            return max(1, min(seq_len, math.ceil(self.mask_ratio * seq_len)))
        return seq_len

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "lamb"        # lamb | lans | lars | nlamb | nnlamb | adam | adamw | adagrad | momentum
    learning_rate: float = 1e-3
    total_steps: int = 100
    warmup_ratio: float = 1.0 / 320.0
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-6
    phi_bounds: Optional[Tuple[float, float]] = None
    grad_clip_norm: Optional[float] = 1.0
    bias_correction: bool = True
    moment_dtype: Optional[str] = None  # e.g. "bfloat16" — halves m/v state
    # --- large-batch scaling knobs (global_batch = microbatch × accum × DP) ---
    accum_steps: int = 1           # gradient-accumulation microbatches per step
    microbatch: Optional[int] = None  # legacy alias for accum_steps (slices)
    precision: str = "fp32"        # fp32 | bf16 (bf16 compute, fp32 masters)
    use_fused_lamb: bool = False   # Pallas/XLA fused LAMB update in the step
    fused_backend: str = "auto"    # auto | pallas | xla | interpret
    seed: int = 0
    # in-jit non-finite guard: one fused all-finite reduction over loss +
    # grads; a non-finite step passes the whole TrainState through unchanged
    # (schedule counters included) and bumps the persisted `skipped` counter
    skip_nonfinite: bool = False
    log_trust_ratios: bool = False
    # per-layer trust-ratio/norm recording: the step returns, under
    # metrics["telemetry/per_layer"], pytrees of per-layer-slice vectors
    # (trust_ratio threaded out of the fused-LAMB kernels as an aux output)
    # — jit-compatible, no host sync until the Trainer's log-step fetch
    record_trust_ratios: bool = False

    @property
    def grad_accum_steps(self) -> int:
        """Effective number of accumulation microbatches (≥ 1).

        ``accum_steps`` is canonical; the legacy ``microbatch`` slice count is
        honored when it asks for more slices.
        """
        return max(self.accum_steps, self.microbatch or 1, 1)

    @property
    def compute_dtype(self) -> Optional[str]:
        """Forward/backward compute dtype implied by ``precision`` (None = native)."""
        if self.precision in ("bf16", "bfloat16"):
            return "bfloat16"
        if self.precision in ("fp32", "float32"):
            return None
        raise ValueError(f"unknown precision {self.precision!r}")
