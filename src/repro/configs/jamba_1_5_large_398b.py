"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2; Mamba:attention 7:1 interleave, MoE every
other layer.  [arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    n_experts_per_tok=2,
    moe_d_ff=24576,
    attn_period=8,           # 1 attention layer per 8 (1:7)
    moe_period_in_block=2,   # MoE every other layer
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    use_rope=False,          # Jamba attention is NoPE
    act_fn="silu",
    norm_type="rmsnorm",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-smoke", n_layers=4, attn_period=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, n_experts=4, n_experts_per_tok=2,
        moe_d_ff=256,
    )
