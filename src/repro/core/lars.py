"""LARS (Algorithm 1) — You et al. 2017, as formalized by this paper.

m_t = b1 * m_{t-1} + (1 - b1) * (g_t + lambda * x_t)
x_{t+1}^(i) = x_t^(i) - eta * phi(||x^(i)||) / ||m^(i)|| * m^(i)
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.strategy import layerwise_adaptation
from repro.optim.base import (
    GradientTransformation,
    PyTree,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    scale_by_learning_rate,
    trace,
)


def lars(
    learning_rate: ScalarOrSchedule,
    momentum: float = 0.9,
    weight_decay: float = 0.0,
    *,
    wd_mask: Optional[PyTree] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
) -> GradientTransformation:
    transforms = []
    if weight_decay:
        # Algorithm 1 folds weight decay into the momentum buffer input.
        transforms.append(add_decayed_weights(weight_decay, wd_mask))
    transforms.append(trace(momentum, average=True))
    transforms.append(
        layerwise_adaptation(
            phi_bounds=phi_bounds, trust_mask=trust_mask, layer_axes=layer_axes
        )
    )
    transforms.append(scale_by_learning_rate(learning_rate))
    return chain(*transforms)
