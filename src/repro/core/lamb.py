"""LAMB (Algorithm 2) — the paper's optimizer.

Composed from the general strategy:  adam-ratio  →  +decoupled weight decay
→  layerwise trust-ratio rescale  →  -lr.  The trust ratio is computed on
``r_t + lambda * x_t`` exactly as Algorithm 2 specifies.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.strategy import layerwise_adaptation
from repro.optim.base import (
    GradientTransformation,
    PyTree,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    scale_by_adam,
    scale_by_learning_rate,
)


def lamb(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[PyTree] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    bias_correction: bool = True,
    grad_clip_norm: Optional[float] = None,
    nesterov_m: bool = False,
    nesterov_v: bool = False,
    moment_dtype=None,
    norm_ord: str = "l2",
) -> GradientTransformation:
    """LAMB optimizer (paper defaults: b1=.9 b2=.999 eps=1e-6 wd=.01).

    Args:
      wd_mask / trust_mask: pytrees of bool — reference impl excludes
        LayerNorm scales and biases from both weight decay and trust scaling.
      layer_axes: stacked-layer axis index per leaf (-1 = unstacked) for
        scan-aware per-layer trust ratios.
      phi_bounds: (gamma_l, gamma_u) clip for phi; None = identity phi.
      bias_correction: False removes adam-correction (App. E).
      nesterov_m / nesterov_v: N-LAMB / NN-LAMB (App. D).
    """
    transforms = []
    if grad_clip_norm is not None:
        transforms.append(clip_by_global_norm(grad_clip_norm))
    transforms.append(
        scale_by_adam(
            b1,
            b2,
            eps,
            bias_correction=bias_correction,
            nesterov_m=nesterov_m,
            nesterov_v=nesterov_v,
            moment_dtype=moment_dtype,
        )
    )
    if weight_decay:
        transforms.append(add_decayed_weights(weight_decay, wd_mask))
    transforms.append(
        layerwise_adaptation(
            phi_bounds=phi_bounds, trust_mask=trust_mask, layer_axes=layer_axes,
            norm_ord=norm_ord,
        )
    )
    transforms.append(scale_by_learning_rate(learning_rate))
    return chain(*transforms)
