"""Learning-rate schedules and the paper's batch-size scaling rules (§4.3).

Reproduces Tables 4–5 exactly:
  * square-root LR scaling:      lr(B) = lr(B0) * sqrt(B/B0)
  * linear-epoch warmup:         warmup_ratio(B) = warmup_ratio(B0) * B/B0
    (warmup covers a fixed number of *epochs*, so its fraction of the — now
    shorter — step budget grows linearly with batch size)
  * polynomial decay:            eta_t = eta_0 * (1 - t/T)
  * re-warmup for mixed-batch stage 2 (§4.1)
  * Goyal et al. step schedule (5-epoch warmup, x0.1 @ 30/60/80) for baselines.

All schedules are jnp-traceable functions of an int32 step count.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence, Tuple

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(value: float) -> Schedule:
    return lambda step: jnp.asarray(value, jnp.float32)


def polynomial_decay(
    base_lr: float, total_steps: int, power: float = 1.0, end_lr: float = 0.0
) -> Schedule:
    """eta_t = end + (eta_0 - end) * (1 - t/T)^power  (paper uses power=1)."""

    def schedule(step):
        frac = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return end_lr + (base_lr - end_lr) * (1.0 - frac) ** power

    return schedule


def linear_warmup(base_lr: float, warmup_steps: int) -> Schedule:
    def schedule(step):
        if warmup_steps <= 0:
            return jnp.asarray(base_lr, jnp.float32)
        return base_lr * jnp.minimum(step.astype(jnp.float32) / warmup_steps, 1.0)

    return schedule


def warmup_poly_decay(
    base_lr: float,
    total_steps: int,
    warmup_steps: int,
    power: float = 1.0,
    end_lr: float = 0.0,
) -> Schedule:
    """BERT/LAMB schedule: linear warmup to base_lr then polynomial decay to 0.

    Decay runs over the post-warmup remainder, starting at base_lr.
    """
    decay = polynomial_decay(base_lr, max(total_steps - warmup_steps, 1), power, end_lr)

    def schedule(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        after = decay(jnp.maximum(step - warmup_steps, 0.0))
        return jnp.where(step < warmup_steps, warm, after) if warmup_steps > 0 else after

    return schedule


def sqrt_scaled_lr(base_lr: float, base_batch: int, batch: int) -> float:
    """Square-root LR scaling rule (Table 4/5: lr = 5/(2^x * 1e3) pattern)."""
    return base_lr * math.sqrt(batch / base_batch)


def linear_epoch_warmup_ratio(base_ratio: float, base_batch: int, batch: int) -> float:
    """Warmup-step *fraction* grows linearly with batch (fixed warmup epochs)."""
    return min(base_ratio * batch / base_batch, 1.0)


def untuned_lamb_schedule(
    batch_size: int,
    total_steps: int,
    *,
    base_lr: float = 5e-3 / 8.0,   # Table 4: 5/(2^3 * 1e3) at batch 512
    base_batch: int = 512,
    base_warmup_ratio: float = 1.0 / 320.0,
    power: float = 1.0,
) -> Tuple[Schedule, dict]:
    """The paper's fully-automatic scaling recipe (Table 4 defaults for BERT).

    Returns (schedule, info) where info records the derived lr/warmup so that
    tests can check them against the paper's table.
    """
    lr = sqrt_scaled_lr(base_lr, base_batch, batch_size)
    ratio = linear_epoch_warmup_ratio(base_warmup_ratio, base_batch, batch_size)
    warmup_steps = int(round(ratio * total_steps))
    sched = warmup_poly_decay(lr, total_steps, warmup_steps, power)
    return sched, {
        "learning_rate": lr,
        "warmup_ratio": ratio,
        "warmup_steps": warmup_steps,
        "total_steps": total_steps,
    }


def piecewise_stage_schedule(
    stage_schedules: Sequence[Schedule], stage_steps: Sequence[int]
) -> Schedule:
    """Concatenate per-stage schedules; each stage's local step restarts at 0.

    Used for mixed-batch training: stage 2 gets its own warmup (*re-warmup*,
    §4.1) instead of continuing stage 1's decay.
    """
    boundaries = []
    acc = 0
    for s in stage_steps:
        boundaries.append(acc)
        acc += s

    def schedule(step):
        step_f = step.astype(jnp.float32)
        out = jnp.asarray(0.0, jnp.float32)
        for sched, start, length in zip(stage_schedules, boundaries, stage_steps):
            local = jnp.clip(step_f - start, 0.0, float(length))
            inside = (step_f >= start) & (step_f < start + length)
            out = jnp.where(inside, sched(local), out)
        # past the end: last stage's final value
        last_sched, last_start = stage_schedules[-1], boundaries[-1]
        out = jnp.where(
            step_f >= last_start + stage_steps[-1],
            last_sched(jnp.asarray(float(stage_steps[-1]))),
            out,
        )
        return out

    return schedule


def goyal_step_schedule(
    base_lr: float,
    steps_per_epoch: int,
    warmup_epochs: float = 5.0,
    milestones: Sequence[int] = (30, 60, 80),
    gamma: float = 0.1,
) -> Schedule:
    """Goyal et al. (2017) ResNet recipe — used for tuned baselines (App. H)."""

    def schedule(step):
        epoch = step.astype(jnp.float32) / max(steps_per_epoch, 1)
        warm = base_lr * epoch / warmup_epochs
        factor = jnp.asarray(1.0, jnp.float32)
        for m in milestones:
            factor = jnp.where(epoch >= m, factor * gamma, factor)
        return jnp.where(epoch < warmup_epochs, warm, base_lr * factor)

    return schedule


def adam_correction_equivalent_lr(
    step: jnp.ndarray, b1: float = 0.9, b2: float = 0.999
) -> jnp.ndarray:
    """App. E: adam bias correction == an implicit LR factor sqrt(1-b2^t)/(1-b1^t).

    Exposed for the App-E validation benchmark (correction ≈ warmup claim).
    """
    t = step.astype(jnp.float32) + 1.0
    return jnp.sqrt(1.0 - b2**t) / (1.0 - b1**t)
