"""repro.core — the paper's contribution: layerwise-adaptive large-batch optimization."""
from repro.core.lamb import lamb
from repro.core.lans import lans, normalize_grads, scale_by_lans
from repro.core.lars import lars
from repro.core.mixed_batch import Stage, bert_mixed_batch_plan, make_stage, scaled_plan
from repro.core.nlamb import nlamb, nnlamb
from repro.core.schedules import (
    adam_correction_equivalent_lr,
    constant,
    goyal_step_schedule,
    linear_epoch_warmup_ratio,
    linear_warmup,
    piecewise_stage_schedule,
    polynomial_decay,
    sqrt_scaled_lr,
    untuned_lamb_schedule,
    warmup_poly_decay,
)
from repro.core.strategy import (
    layerwise_adapt,
    layerwise_adaptation,
    phi_clip,
    trust_ratio,
)
from repro.core.trust_ratio import (
    summarize_trust_ratios,
    trust_ratio_tree,
    trust_records,
)

__all__ = [
    "Stage",
    "adam_correction_equivalent_lr",
    "bert_mixed_batch_plan",
    "constant",
    "goyal_step_schedule",
    "lamb",
    "lans",
    "lars",
    "layerwise_adapt",
    "layerwise_adaptation",
    "linear_epoch_warmup_ratio",
    "linear_warmup",
    "make_stage",
    "nlamb",
    "nnlamb",
    "normalize_grads",
    "phi_clip",
    "piecewise_stage_schedule",
    "polynomial_decay",
    "scale_by_lans",
    "scaled_plan",
    "sqrt_scaled_lr",
    "summarize_trust_ratios",
    "trust_ratio",
    "trust_ratio_tree",
    "trust_records",
    "untuned_lamb_schedule",
    "warmup_poly_decay",
]
