"""The paper's §3 *general layerwise adaptation strategy* as a combinator.

Given any base optimizer whose update direction is ``u_t`` the strategy
rescales each layer's update to

    x_{t+1}^(i) = x_t^(i) - eta * phi(||x_t^(i)||) / ||u_t^(i)|| * u_t^(i)

with ``phi(z) = clip(z, gamma_l, gamma_u)``.  Instantiations:

    layerwise_adapt(momentum-with-wd)  == LARS   (Algorithm 1)
    layerwise_adapt(adam ∘ +wd)        == LAMB   (Algorithm 2)

Two production details beyond the pseudocode (both match the reference
TensorFlow implementation the paper links):

  * degenerate norms: trust ratio falls back to 1 when either ||x|| or ||u||
    is zero (otherwise zero-initialized layers could never move);
  * exclusions: norm scales and biases bypass the ratio (``trust_mask``).

**Scan-aware layerwise semantics**: deep stacks are stored as single stacked
leaves (leading ``layers`` axis, consumed by ``lax.scan``).  ``layer_axes``
gives the stacked-axis index per leaf; norms are then computed *per layer
slice*, reproducing exactly the per-layer trust ratios of an unstacked model.

**Mixed-precision safety**: every norm here upcasts to fp32 before the
reduction (``_slice_norm``), so bf16 params/updates keep full dynamic range
in the trust ratio — a ratio of two fp32 norms — even when the forward ran
in half precision.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.base import EmptyState, GradientTransformation, PyTree


def phi_clip(z: jnp.ndarray, bounds: Optional[Tuple[float, float]]) -> jnp.ndarray:
    """phi(z) = min(max(z, gamma_l), gamma_u); identity when bounds is None."""
    if bounds is None:
        return z
    lo, hi = bounds
    return jnp.clip(z, lo, hi)


def _slice_norm(
    x: jnp.ndarray, layer_axis: Optional[int], ord: str = "l2"
) -> jnp.ndarray:
    """Norm over all axes except the stacked-layers axis (broadcastable).

    App. F of the paper ablates the norm choice (L1 / L2 / L∞) and finds
    <0.1% accuracy difference; L2 is the default.
    """
    x = x.astype(jnp.float32)
    if layer_axis is None or layer_axis < 0:
        axes = None
        keep = False
    else:
        axes = tuple(i for i in range(x.ndim) if i != layer_axis)
        keep = True
    if ord == "l1":
        return jnp.sum(jnp.abs(x), axis=axes, keepdims=keep)
    if ord == "linf":
        return jnp.max(jnp.abs(x), axis=axes, keepdims=keep)
    return jnp.sqrt(jnp.sum(jnp.square(x), axis=axes, keepdims=keep))


def trust_ratio(
    param: jnp.ndarray,
    update: jnp.ndarray,
    *,
    layer_axis: Optional[int] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    eps: float = 0.0,
    norm_ord: str = "l2",
) -> jnp.ndarray:
    """phi(||x||)/||u|| with the reference-impl degenerate-norm fallbacks.

    Args: ``param``/``update`` = x_t and u_t of Algorithm 2; ``layer_axis``
    keeps that axis for per-slice ratios on scanned stacks; ``phi_bounds``
    clips the weight norm; ``norm_ord`` picks the App. F norm.  Returns a
    scalar (unstacked) or a broadcastable per-layer array.  Invariant: the
    ratio is 1 wherever either norm is zero, and always a ratio of fp32
    reductions regardless of input dtype.
    """
    w_norm = phi_clip(_slice_norm(param, layer_axis, norm_ord), phi_bounds)
    u_norm = _slice_norm(update, layer_axis, norm_ord)
    safe = w_norm / (u_norm + eps)
    ratio = jnp.where(w_norm > 0, jnp.where(u_norm > 0, safe, 1.0), 1.0)
    return ratio


def layerwise_adaptation(
    *,
    phi_bounds: Optional[Tuple[float, float]] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
    eps: float = 0.0,
    norm_ord: str = "l2",   # l2 | l1 | linf  (App. F ablation)
) -> GradientTransformation:
    """GradientTransformation applying the layerwise trust-ratio rescale.

    Args: ``phi_bounds`` = (gamma_l, gamma_u) clip for phi; ``trust_mask``
    excludes leaves (False = update passes through untouched); ``layer_axes``
    marks stacked-layer axes (-1/None = unstacked).  Returns a stateless
    transform.  Invariant: after this transform a masked-in leaf's update
    norm is ``phi(||x||)`` per layer slice — multiply by -lr downstream to
    get Algorithm 2's ``eta * phi / ||u||`` step.
    """

    def init(params):
        return EmptyState()

    def update(updates, state, params=None):
        if params is None:
            raise ValueError("layerwise_adaptation requires params")

        # None is a pytree-empty node, so "no stacked axis" is encoded as -1.
        la = layer_axes
        if la is None:
            la = jax.tree.map(lambda _: -1, updates)
        else:
            la = jax.tree.map(lambda a: -1 if a is None else a, la,
                              is_leaf=lambda x: x is None or isinstance(x, int))
        tm = trust_mask
        if tm is None:
            tm = jax.tree.map(lambda _: True, updates)

        def one(u, p, axis, masked_in):
            if not masked_in:
                return u
            r = trust_ratio(p, u, layer_axis=axis, phi_bounds=phi_bounds,
                            eps=eps, norm_ord=norm_ord)
            return (r * u.astype(jnp.float32)).astype(u.dtype)

        new_updates = jax.tree.map(one, updates, params, la, tm)
        return new_updates, state

    return GradientTransformation(init, update)


def layerwise_adapt(
    base: GradientTransformation,
    *,
    phi_bounds: Optional[Tuple[float, float]] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
) -> GradientTransformation:
    """The paper's general strategy: wrap any base optimizer A.

    Note the learning rate must be applied *after* this wrapper (the wrapper
    normalizes whatever direction the base produces).
    """
    from repro.optim.base import chain

    return chain(
        base,
        layerwise_adaptation(
            phi_bounds=phi_bounds, trust_mask=trust_mask, layer_axes=layer_axes
        ),
    )
