"""LANS — Accelerated Large Batch Optimization of BERT Pretraining in 54
minutes (Zheng et al., 2020; PAPERS.md).

LANS modifies LAMB in two ways:

  * **block-normalized gradients**: each layer's gradient is normalized to
    unit L2 norm *before* entering the Adam moments, so the moment
    statistics see direction only — a large-batch variance-reduction trick;
  * **Nesterov-style two-term update**: the step mixes the momentum
    direction ``d = m̂/(√v̂+ε) + λx`` and the *current* normalized-gradient
    direction ``d' = g̃/(√v̂+ε) + λx`` with weights ``β1 / (1-β1)``, and —
    the part that makes it LANS rather than NAdam-with-trust — **each term
    gets its own layerwise trust ratio**:

        x ← x − η·[ β1·(φ(‖x‖)/‖d‖)·d + (1−β1)·(φ(‖x‖)/‖d'‖)·d' ]

Composed as ``chain(scale_by_lans, scale_by_learning_rate)`` so the stage-2
re-warm-up reset (``_reset_schedule_counts``) zeroes the schedule counter
while the moments — held in a ``ScaleByAdamState`` with the same tree
structure as LAMB's, so FSDP placement and checkpoint restore are
identical — carry across stages.

Layerwise semantics match ``core/strategy.py`` exactly: scan-stacked leaves
get per-layer-slice norms via ``layer_axes``, every norm reduction runs in
fp32, degenerate norms fall back to ratio 1 (and an all-zero gradient block
passes through unnormalized), and ``trust_mask`` excludes norm scales and
biases from both trust rescales (``wd_mask`` from the λx terms).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.strategy import _slice_norm, trust_ratio
from repro.optim.base import (
    GradientTransformation,
    PyTree,
    ScalarOrSchedule,
    ScaleByAdamState,
    chain,
    clip_by_global_norm,
    scale_by_learning_rate,
)


def _resolve_axes(layer_axes: Optional[PyTree], tree: PyTree) -> PyTree:
    """Per-leaf stacked-axis tree with -1 meaning "unstacked" (None is a
    pytree-empty node, so it cannot ride the tree directly)."""
    if layer_axes is None:
        return jax.tree.map(lambda _: -1, tree)
    return jax.tree.map(
        lambda a: -1 if a is None else a, layer_axes,
        is_leaf=lambda x: x is None or isinstance(x, int),
    )


def normalize_grads(
    grads: PyTree,
    *,
    layer_axes: Optional[PyTree] = None,
    norm_ord: str = "l2",
) -> PyTree:
    """g̃ = g / ‖g‖ per layer block (per slice on scanned stacks), fp32.

    An all-zero block passes through unchanged — the same degenerate-norm
    fallback the trust ratio uses, so zero-initialized layers never divide
    by zero.
    """
    la = _resolve_axes(layer_axes, grads)

    def one(g, axis):
        g32 = g.astype(jnp.float32)
        n = _slice_norm(g32, axis, norm_ord)
        return jnp.where(n > 0, g32 / jnp.where(n > 0, n, 1.0), g32)

    return jax.tree.map(one, grads, la)


def scale_by_lans(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[PyTree] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    bias_correction: bool = True,
    moment_dtype=None,
    norm_ord: str = "l2",
) -> GradientTransformation:
    """The LANS direction: normalized-gradient Adam moments + the two-term
    Nesterov update, each term trust-rescaled per layer.

    Returns *positive* directions — chain with ``scale_by_learning_rate``
    for the −η step.  State is a ``ScaleByAdamState`` (count, mu, nu): the
    count drives bias correction and must NOT be reset at a stage switch
    (the schedule counter lives in the downstream ``ScheduleState``).
    """
    mdt = jnp.dtype(moment_dtype) if moment_dtype is not None else jnp.float32

    def init(params):
        zeros = lambda: jax.tree.map(lambda x: jnp.zeros_like(x, mdt), params)
        return ScaleByAdamState(count=jnp.zeros([], jnp.int32),
                                mu=zeros(), nu=zeros())

    def update(updates, state, params=None):
        if params is None:
            raise ValueError("scale_by_lans requires params")
        la = _resolve_axes(layer_axes, updates)
        tm = trust_mask if trust_mask is not None else jax.tree.map(
            lambda _: True, updates)
        wm = wd_mask if wd_mask is not None else jax.tree.map(
            lambda _: True, updates)

        count = state.count + 1
        t = count.astype(jnp.float32)
        c1 = (1.0 - b1**t) if bias_correction else 1.0
        c2 = (1.0 - b2**t) if bias_correction else 1.0

        def one(g, x, m, v, axis, trusted, decayed):
            g32 = g.astype(jnp.float32)
            gn = _slice_norm(g32, axis, norm_ord)
            g_tilde = jnp.where(gn > 0, g32 / jnp.where(gn > 0, gn, 1.0), g32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g_tilde
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g_tilde * g_tilde
            denom = jnp.sqrt(v_new / c2) + eps
            wd = weight_decay * x.astype(jnp.float32) if (
                weight_decay and decayed) else 0.0
            d_m = (m_new / c1) / denom + wd      # momentum direction
            d_g = g_tilde / denom + wd           # current-gradient direction
            if trusted:
                r_m = trust_ratio(x, d_m, layer_axis=axis,
                                  phi_bounds=phi_bounds, norm_ord=norm_ord)
                r_g = trust_ratio(x, d_g, layer_axis=axis,
                                  phi_bounds=phi_bounds, norm_ord=norm_ord)
            else:
                r_m = r_g = 1.0
            u = b1 * r_m * d_m + (1 - b1) * r_g * d_g
            return u, m_new.astype(mdt), v_new.astype(mdt)

        out = jax.tree.map(one, updates, params, state.mu, state.nu, la, tm, wm)
        # unzip the (u, m, v) leaf triples into three trees
        treedef = jax.tree.structure(updates)
        triples = jax.tree.leaves(out, is_leaf=lambda n: isinstance(n, tuple))
        new_updates = jax.tree.unflatten(treedef, [o[0] for o in triples])
        mu = jax.tree.unflatten(treedef, [o[1] for o in triples])
        nu = jax.tree.unflatten(treedef, [o[2] for o in triples])
        return new_updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def lans(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    *,
    wd_mask: Optional[PyTree] = None,
    trust_mask: Optional[PyTree] = None,
    layer_axes: Optional[PyTree] = None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    bias_correction: bool = True,
    grad_clip_norm: Optional[float] = None,
    moment_dtype=None,
    norm_ord: str = "l2",
) -> GradientTransformation:
    """LANS optimizer (Zheng et al. defaults match LAMB's: b1=.9 b2=.999).

    Same signature family as :func:`repro.core.lamb.lamb`; the global-norm
    gradient clip (when set) runs *before* the per-block normalization —
    normalization then removes its magnitude effect on masked-in blocks,
    which is exactly the point: LANS is clip-insensitive by construction.
    """
    transforms = []
    if grad_clip_norm is not None:
        transforms.append(clip_by_global_norm(grad_clip_norm))
    transforms.append(
        scale_by_lans(
            b1, b2, eps, weight_decay,
            wd_mask=wd_mask, trust_mask=trust_mask, layer_axes=layer_axes,
            phi_bounds=phi_bounds, bias_correction=bias_correction,
            moment_dtype=moment_dtype, norm_ord=norm_ord,
        )
    )
    transforms.append(scale_by_learning_rate(learning_rate))
    return chain(*transforms)
