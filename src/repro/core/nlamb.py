"""N-LAMB and NN-LAMB (paper Appendix D, Algorithms 3–4).

Nesterov momentum folded into LAMB's first (N-LAMB) or both (NN-LAMB)
moments.  Dozat (2016) settings: b1=0.975, b2=0.999, eps=1e-8.
"""
from __future__ import annotations

from repro.core.lamb import lamb
from repro.optim.base import GradientTransformation, ScalarOrSchedule


def nlamb(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.975,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    **kw,
) -> GradientTransformation:
    return lamb(
        learning_rate, b1, b2, eps, weight_decay, nesterov_m=True, **kw
    )


def nnlamb(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.975,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    **kw,
) -> GradientTransformation:
    return lamb(
        learning_rate, b1, b2, eps, weight_decay,
        nesterov_m=True, nesterov_v=True, **kw,
    )
