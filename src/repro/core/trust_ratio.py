"""Trust-ratio diagnostics (paper App. H Figures 9-14).

The trainer can log per-layer trust ratios phi(||x||)/||u|| every step; these
are the quantities the paper plots to show LAMB "helping slow learners".
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.strategy import _slice_norm, phi_clip


def trust_ratio_tree(
    params,
    updates,
    *,
    layer_axes=None,
    phi_bounds: Optional[Tuple[float, float]] = None,
):
    """Tree of per-leaf (or per-layer-slice) trust ratios, squeezed to vectors."""
    la = layer_axes
    if la is None:
        la = jax.tree.map(lambda _: -1, params)
    else:
        la = jax.tree.map(
            lambda a: -1 if a is None else a, la,
            is_leaf=lambda x: x is None or isinstance(x, int),
        )

    def one(p, u, axis):
        w = phi_clip(_slice_norm(p, axis), phi_bounds)
        g = _slice_norm(u, axis)
        r = jnp.where(w > 0, jnp.where(g > 0, w / g, 1.0), 1.0)
        return jnp.squeeze(r)

    return jax.tree.map(one, params, updates, la)


def _normalize_axes(params, layer_axes):
    if layer_axes is None:
        return jax.tree.map(lambda _: -1, params)
    return jax.tree.map(
        lambda a: -1 if a is None else a, layer_axes,
        is_leaf=lambda x: x is None or isinstance(x, int),
    )


def trust_records(
    params,
    updates,
    *,
    layer_axes=None,
    phi_bounds: Optional[Tuple[float, float]] = None,
    trust_ratio=None,
):
    """Per-layer recording pytrees for the telemetry recorder.

    Returns ``{"trust_ratio", "param_norm", "update_norm"}`` — three trees
    shaped like ``params`` whose leaves are per-layer-slice vectors
    (squeezed scalars on unstacked leaves).  ``trust_ratio`` lets the fused
    path pass the *applied* ratio (the kernels' aux output) instead of the
    post-hoc ``phi(||x||)/||Δx||`` recomputation used on the unfused chain.
    All jnp, jit-compatible, no host sync.
    """
    la = _normalize_axes(params, layer_axes)
    if trust_ratio is None:
        trust_ratio = trust_ratio_tree(
            params, updates, layer_axes=layer_axes, phi_bounds=phi_bounds
        )
    norm = lambda t: jax.tree.map(
        lambda x, a: jnp.squeeze(_slice_norm(x, a)), t, la
    )
    return {
        "trust_ratio": jax.tree.map(jnp.squeeze, trust_ratio),
        "param_norm": norm(params),
        "update_norm": norm(updates),
    }


def summarize_trust_ratios(tree) -> dict:
    leaves = [jnp.atleast_1d(x) for x in jax.tree.leaves(tree)]
    flat = jnp.concatenate([x.reshape(-1) for x in leaves]) if leaves else jnp.zeros((1,))
    return {
        "trust_ratio/min": jnp.min(flat),
        "trust_ratio/max": jnp.max(flat),
        "trust_ratio/mean": jnp.mean(flat),
    }
