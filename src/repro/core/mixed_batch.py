"""Mixed-batch training (paper §4.1) — the 76-minute BERT recipe.

BERT pre-training is two-phase: 9/10 of epochs at seq 128, 1/10 at seq 512.
The paper's observation: phase 1 can use a much larger batch (65536) than the
phase-2 memory limit (32768), and phase 2 must *re-warm-up* the LR from zero
because switching sequence length changes the optimization problem.

This module describes the stage plan declaratively; the Trainer re-jits per
stage (shapes change between stages).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.schedules import (
    Schedule,
    linear_epoch_warmup_ratio,
    sqrt_scaled_lr,
    untuned_lamb_schedule,
    warmup_poly_decay,
)


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    seq_len: int
    batch_size: int
    steps: int
    schedule: Schedule
    learning_rate: float
    warmup_steps: int


def make_stage(
    name: str,
    seq_len: int,
    batch_size: int,
    steps: int,
    *,
    base_lr: float = 5e-3 / 8.0,
    base_batch: int = 512,
    base_warmup_ratio: float = 1.0 / 320.0,
) -> Stage:
    lr = sqrt_scaled_lr(base_lr, base_batch, batch_size)
    ratio = linear_epoch_warmup_ratio(base_warmup_ratio, base_batch, batch_size)
    warmup = int(round(ratio * steps))
    sched = warmup_poly_decay(lr, steps, warmup)
    return Stage(name, seq_len, batch_size, steps, sched, lr, warmup)


def bert_mixed_batch_plan(
    *,
    seq1: int = 128,
    seq2: int = 512,
    batch1: int = 65536,
    batch2: int = 32768,
    steps1: int = 7038,
    steps2: int = 1561,
    base_lr: float = 5e-3 / 8.0,
    base_batch: int = 512,
    base_warmup_ratio: float = 1.0 / 320.0,
) -> List[Stage]:
    """The paper's 8599-iteration mixed-batch recipe (64K/32K).

    Stage step counts follow the paper: 8599 total iterations; each stage has
    its own sqrt-scaled LR and its own warmup (stage 2 = re-warm-up from 0).
    """
    mk = lambda *a: make_stage(
        *a, base_lr=base_lr, base_batch=base_batch, base_warmup_ratio=base_warmup_ratio
    )
    return [
        mk("stage1_seq128", seq1, batch1, steps1),
        mk("stage2_seq512_rewarmup", seq2, batch2, steps2),
    ]


def scaled_plan(
    plan: Sequence[Stage], *, batch_divisor: int = 1, step_divisor: int = 1
) -> List[Stage]:
    """Shrink a plan for CPU-scale validation runs, preserving its structure."""
    out = []
    for s in plan:
        batch = max(s.batch_size // batch_divisor, 1)
        steps = max(s.steps // step_divisor, 2)
        out.append(
            make_stage(s.name, s.seq_len, batch, steps)
        )
    return out
