from repro.serve.continuous import (
    ContinuousEngine,
    make_pool_decode_step,
    make_pool_prefill,
    serving_stats,
)
from repro.serve.engine import Engine, Request, make_decode_step, make_prefill_step
from repro.serve.faults import (
    SERVE_FAULT_KINDS,
    ServeFaultInjector,
    ServeFaultSpec,
    parse_fault_specs,
)
from repro.serve.kv_pool import KVPool
from repro.serve.sampling import sample_tokens, top_k_mask
from repro.serve.scheduler import (
    TERMINAL_STATUSES,
    FCFSScheduler,
    RequestStatus,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    request_tokens,
    trace_arrivals,
)

__all__ = [
    "ContinuousEngine",
    "Engine",
    "FCFSScheduler",
    "KVPool",
    "Request",
    "RequestStatus",
    "SERVE_FAULT_KINDS",
    "ServeFaultInjector",
    "ServeFaultSpec",
    "ServeRequest",
    "TERMINAL_STATUSES",
    "assign_arrivals",
    "make_decode_step",
    "make_pool_decode_step",
    "make_pool_prefill",
    "make_prefill_step",
    "parse_fault_specs",
    "poisson_arrivals",
    "request_tokens",
    "sample_tokens",
    "serving_stats",
    "top_k_mask",
    "trace_arrivals",
]
