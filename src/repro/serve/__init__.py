from repro.serve.continuous import (
    ContinuousEngine,
    make_pool_decode_step,
    make_pool_prefill,
    serving_stats,
)
from repro.serve.engine import Engine, Request, make_decode_step, make_prefill_step
from repro.serve.kv_pool import KVPool
from repro.serve.sampling import sample_tokens, top_k_mask
from repro.serve.scheduler import (
    FCFSScheduler,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    trace_arrivals,
)

__all__ = [
    "ContinuousEngine",
    "Engine",
    "FCFSScheduler",
    "KVPool",
    "Request",
    "ServeRequest",
    "assign_arrivals",
    "make_decode_step",
    "make_pool_decode_step",
    "make_pool_prefill",
    "make_prefill_step",
    "poisson_arrivals",
    "sample_tokens",
    "serving_stats",
    "top_k_mask",
    "trace_arrivals",
]
