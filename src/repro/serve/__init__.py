from repro.serve.engine import Engine, Request, make_decode_step, make_prefill_step

__all__ = ["Engine", "Request", "make_decode_step", "make_prefill_step"]
