"""Deterministic fault injection for the serving reliability harness.

The serving twin of ``train/faults.py``: a :class:`ServeFaultInjector` is a
pure state machine the ``ContinuousEngine`` consults at fixed points in a
request's lifecycle, so the same spec list over the same workload produces
the same fault sequence — and therefore the same terminal-state counts —
on every replay (``reset()`` rewinds the fired-set for a second run).

Three kinds, keyed like the training injector by a **deterministic
ordinal**, never by wall time:

* ``sample_nan`` — keyed by request id: the request's first sampled token
  of the current attempt is reported non-finite.  The engine treats it as
  a transient failure: the slot is freed immediately and the request is
  requeued with a bounded retry/backoff budget (exhausted retries surface
  as ``FAILED``, never as a silent drop).
* ``slot_corrupt`` — keyed by request id: the slot's KV state is reported
  corrupted after prefill.  Same retry path as ``sample_nan``, but the
  slot itself is **quarantined** — evicted and withheld from the free
  list for a cool-down — before the request is requeued.
* ``decode_stall`` — keyed by the *decode-step ordinal within the current
  generate run*: the step blocks for ``stall_s`` seconds, the signature
  of a hiccuping accelerator.  Drives the engine's stall watchdog
  (degraded-mode admission caps + ``serve_degraded`` event).

``once=True`` (default) faults fire a single time — the retry succeeds,
proving the recovery path; ``once=False`` faults re-fire on every attempt
— the retry budget exhausts, proving the failure surface.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

SERVE_FAULT_KINDS = ("sample_nan", "slot_corrupt", "decode_stall")


@dataclasses.dataclass(frozen=True)
class ServeFaultSpec:
    """One planned serving fault.

    ``at`` is the request id for ``sample_nan``/``slot_corrupt`` and the
    in-run decode-step ordinal for ``decode_stall``; ``at < 0`` fires on
    every ordinal (persistent fault).  ``stall_s`` is the injected stall
    duration (``decode_stall`` only).  ``once=True`` fires a non-negative
    ``at`` a single time even when the ordinal recurs (a retried request,
    a replayed step).
    """

    kind: str
    at: int
    stall_s: float = 0.05
    once: bool = True

    def __post_init__(self):
        if self.kind not in SERVE_FAULT_KINDS:
            raise ValueError(
                f"unknown serve fault kind {self.kind!r}; "
                f"one of {SERVE_FAULT_KINDS}"
            )
        if self.stall_s < 0:
            raise ValueError(f"stall_s must be >= 0, got {self.stall_s}")


class ServeFaultInjector:
    """Deterministic, replayable fault source for the continuous engine."""

    def __init__(self, faults: Iterable[ServeFaultSpec]):
        self.faults: Tuple[ServeFaultSpec, ...] = tuple(faults)
        self._fired: Dict[int, int] = {}  # spec index -> fire count

    def _fire(self, idx: int, spec: ServeFaultSpec) -> bool:
        if spec.at >= 0 and spec.once and self._fired.get(idx, 0):
            return False
        self._fired[idx] = self._fired.get(idx, 0) + 1
        return True

    def fire_request(self, rid: int) -> Optional[str]:
        """The fault kind (if any) striking request ``rid``'s current
        attempt.  ``slot_corrupt`` outranks ``sample_nan`` when both match
        (the stronger failure decides the slot's fate); at most one fires
        per call so counts stay exact."""
        hit: Optional[Tuple[int, ServeFaultSpec]] = None
        for idx, f in enumerate(self.faults):
            if f.kind == "decode_stall" or (f.at >= 0 and f.at != rid):
                continue
            if f.at >= 0 and f.once and self._fired.get(idx, 0):
                continue
            if hit is None or (f.kind == "slot_corrupt"
                               and hit[1].kind != "slot_corrupt"):
                hit = (idx, f)
        if hit is None:
            return None
        self._fire(*hit)
        return hit[1].kind

    def stall_s(self, step_ordinal: int) -> float:
        """Total injected stall for decode step ``step_ordinal`` (0 when
        no ``decode_stall`` spec matches)."""
        total = 0.0
        for idx, f in enumerate(self.faults):
            if f.kind != "decode_stall":
                continue
            if f.at >= 0 and f.at != step_ordinal:
                continue
            if self._fire(idx, f):
                total += f.stall_s
        return total

    def fire_counts(self) -> Dict[str, int]:
        """Fires so far per kind (diagnostics / replay assertions)."""
        out: Dict[str, int] = {}
        for idx, n in self._fired.items():
            kind = self.faults[idx].kind
            out[kind] = out.get(kind, 0) + n
        return out

    def reset(self) -> None:
        """Rewind the fired-set: the next run replays the same sequence."""
        self._fired.clear()


def parse_fault_specs(text: str) -> List[ServeFaultSpec]:
    """Parse a CLI fault list: ``kind@at[:persist][:stall=SECONDS]``
    entries separated by commas.

    >>> [f.kind for f in parse_fault_specs("sample_nan@1,slot_corrupt@2:persist")]
    ['sample_nan', 'slot_corrupt']
    >>> parse_fault_specs("decode_stall@3:stall=0.2")[0].stall_s
    0.2
    """
    specs: List[ServeFaultSpec] = []
    for entry in filter(None, (e.strip() for e in text.split(","))):
        parts = entry.split(":")
        head = parts[0]
        if "@" not in head:
            raise ValueError(
                f"bad fault spec {entry!r}: expected kind@ordinal"
            )
        kind, at = head.split("@", 1)
        once = True
        stall = 0.05
        for opt in parts[1:]:
            if opt == "persist":
                once = False
            elif opt == "once":
                once = True
            elif opt.startswith("stall="):
                stall = float(opt[len("stall="):])
            else:
                raise ValueError(f"bad fault spec option {opt!r} in {entry!r}")
        specs.append(ServeFaultSpec(kind=kind, at=int(at), stall_s=stall,
                                    once=once))
    return specs
