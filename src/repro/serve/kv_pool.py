"""Slot-based KV cache pool for continuous batching.

The pool holds one ``Model.make_cache`` pytree whose batch axis is the slot
axis — every cache family (dense/GQA KV, MLA latent, mamba/xLSTM recurrent
state, hybrid mixtures) goes through it unchanged.  Two representation rules:

* every non-``index`` leaf keeps the stacked layout ``(n_layers, B, ...)``
  produced by ``make_cache`` — batch (slot) axis is always axis 1;
* ``index`` leaves, which ``make_cache`` emits as one scalar length per layer
  ``(n_layers,)``, are widened to per-slot lengths ``(n_layers, B)``.  The
  attention/MLA decode paths accept this vector form and scatter each row at
  its own position.

All device ops (insert, evict, reset-inactive) are jit'd once with donated
pool buffers; the slot id is a traced scalar, so swapping requests between
decode steps never recompiles.  The free-list and a host mirror of per-slot
lengths live on the host — the scheduler reads those, never the device.
"""
from __future__ import annotations

import functools
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


def _is_index(path) -> bool:
    last = path[-1] if path else None
    return isinstance(last, jax.tree_util.DictKey) and last.key == "index"


def widen_index(cache: Any, n_slots: int) -> Any:
    """(n_layers,) scalar-per-layer index leaves → (n_layers, n_slots) zeros."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: jnp.zeros(leaf.shape + (n_slots,), leaf.dtype)
        if _is_index(p) else leaf,
        cache,
    )


def expand_index(cache: Any) -> Any:
    """Single-request cache: index leaves (n_layers,) → (n_layers, 1) so the
    tree matches the pool layout (batch axis on every leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: leaf[..., None] if _is_index(p) else leaf, cache
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _insert(pool: Any, single: Any, slot: jnp.ndarray) -> Any:
    """Copy a prefilled single-request cache (batch axis == 1, same max_len)
    into slot `slot` along axis 1 of every leaf."""
    return jax.tree.map(
        lambda p, s: jax.lax.dynamic_update_slice_in_dim(
            p, s.astype(p.dtype), slot, axis=1
        ),
        pool, single,
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _evict(pool: Any, slot: jnp.ndarray) -> Any:
    """Zero the slot's length.  Stale K/V stay in memory but are masked out
    (valid < 1) and fully overwritten by the next insert."""
    def zero_col(path, leaf):
        if not _is_index(path):
            return leaf
        col = jnp.zeros(leaf.shape[:-1] + (1,), leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, col, slot, axis=leaf.ndim - 1
        )

    return jax.tree_util.tree_map_with_path(zero_col, pool)


def reset_inactive(cache: Any, active: jnp.ndarray) -> Any:
    """Clamp index leaves of inactive slots back to 0 (active: (B,) bool).

    Called inside the decode step so empty slots never walk their write
    position past position 0 while idling.
    """
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: jnp.where(active[None, :], leaf, 0)
        if _is_index(p) else leaf,
        cache,
    )


class KVPool:
    """Fixed-capacity slot pool over a model's cache pytree.

    Args: the model (for ``make_cache``), ``n_slots`` concurrent requests,
    ``max_len`` cache positions per slot.  Invariant: ``lengths[s] > 0``
    iff slot ``s`` is occupied, and the host free-list / lengths mirror is
    the single source of truth the scheduler reads — no device sync needed
    for admission decisions.
    """

    def __init__(self, model: Model, n_slots: int, max_len: int):
        if n_slots < 1 or max_len < 1:
            raise ValueError(
                f"pool needs n_slots >= 1 and max_len >= 1, got "
                f"{n_slots=} {max_len=}"
            )
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = widen_index(model.make_cache(n_slots, max_len), n_slots)
        self.lengths = np.zeros(n_slots, np.int32)  # host mirror of index
        self._free: List[int] = list(range(n_slots - 1, -1, -1))

    # ---- host-side slot bookkeeping ----
    @property
    def n_free(self) -> int:
        """Free slots right now (host-side, O(1))."""
        return len(self._free)

    @property
    def active_mask(self) -> np.ndarray:
        """(n_slots,) bool host array: True where a request occupies a slot."""
        return self.lengths > 0

    def acquire(self) -> Optional[int]:
        """Pop a free slot id (lowest first), or None when full."""
        return self._free.pop() if self._free else None

    # ---- device ops ----
    def insert(self, single_cache: Any, slot: int, length: int) -> None:
        """Install a prefilled batch-1 cache (built at this pool's max_len)
        into `slot`.  `length` is the prompt length already written."""
        if length > self.max_len:
            raise ValueError(f"prompt length {length} exceeds pool max_len "
                             f"{self.max_len}")
        self.cache = _insert(
            self.cache, expand_index(single_cache), jnp.int32(slot)
        )
        self.lengths[slot] = length

    def evict(self, slot: int) -> None:
        """Free `slot` and zero its length on device."""
        if self.lengths[slot] == 0 and slot in self._free:
            return
        self.cache = _evict(self.cache, jnp.int32(slot))
        self.lengths[slot] = 0
        self._free.append(slot)

    def quarantine(self, slot: int) -> None:
        """Evict `slot` *without* returning it to the free list (suspected
        state corruption).  The slot is unschedulable until `release`."""
        self.evict(slot)
        self._free.remove(slot)

    def release(self, slot: int) -> None:
        """Return a quarantined slot to the free list (its device state was
        already zeroed by `quarantine`; the next insert overwrites it)."""
        if slot in self._free or self.lengths[slot] > 0:
            raise ValueError(f"slot {slot} is not quarantined")
        self._free.append(slot)

    def reset(self) -> None:
        """Evict everything (used between benchmark phases)."""
        for slot in range(self.n_slots):
            if self.lengths[slot] > 0:
                self.evict(slot)
