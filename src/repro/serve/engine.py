"""Serving engine: prefill + decode steps and a batched-request driver.

``serve_step`` (single-token decode over a fixed-size cache) is the function
the decode-shaped dry-runs lower.  The :class:`Engine` adds a minimal batched
greedy/temperature generation loop over the jit'd steps — the end-to-end
serving example uses it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.serve.sampling import sample_tokens
from repro.sharding.context import ShardCtx, use_sharding


def make_prefill_step(model: Model):
    """prefill_step(params, batch, cache) -> (last_logits(B,V), cache).

    Invariant: the returned cache holds every prompt position, so the first
    decode step can start at position ``prompt_len``.
    """

    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        last = logits[:, -1]
        return last, cache

    return prefill_step


def make_decode_step(model: Model):
    """One-token step: (params, cache, tokens(B,1), positions(B,1)) → logits.

    Returns (logits(B,V), cache).  Invariant: fixed shapes — one jit
    compilation serves the whole decode loop (and the dry-run lowers it).
    """

    def decode_step(params, cache, tokens, positions):
        logits, cache = model.decode(params, {"tokens": tokens}, cache, positions)
        return logits[:, -1], cache

    return decode_step


@dataclasses.dataclass
class Request:
    """One static-batch generation request (temperature 0 = greedy);
    ``out_tokens``/``latency_s`` are filled in by ``generate_batch``."""

    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[np.ndarray] = None
    latency_s: float = 0.0


class Engine:
    """Static-batch generation engine (greedy / temperature sampling)."""

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_len: int = 512,
        shard_ctx: Optional[ShardCtx] = None,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.shard_ctx = shard_ctx
        self.rng = jax.random.key(seed)
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    def _sample(self, logits, temperatures: jnp.ndarray):
        """Per-row sampling: each request keeps its own temperature."""
        self.rng, sub = jax.random.split(self.rng)
        return sample_tokens(sub, logits, temperatures)

    def generate_batch(self, requests: List[Request]) -> List[Request]:
        """Pad prompts to a common length, prefill once, decode to the
        slowest request's budget.

        Args: a list of :class:`Request`.  Returns the same list with
        ``out_tokens`` (each trimmed to its own ``max_new_tokens``) and a
        shared ``latency_s`` filled in.  Invariant: the whole batch decodes
        in lock-step — a short request waits on the longest one (the
        limitation ContinuousEngine removes).
        """
        t0 = time.perf_counter()
        b = len(requests)
        s = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, s), np.int32)
        for i, r in enumerate(requests):
            toks[i, : len(r.prompt)] = r.prompt  # left-aligned, zero-padded
        max_new = max(r.max_new_tokens for r in requests)
        temps = jnp.asarray([r.temperature for r in requests], jnp.float32)
        # all-greedy (the default): skip sampling and leave the rng untouched
        greedy = max(r.temperature for r in requests) <= 0.0
        sample = (
            (lambda logits: jnp.argmax(logits, axis=-1)) if greedy
            else (lambda logits: self._sample(logits, temps))
        )

        with use_sharding(self.shard_ctx):
            cache = self.model.make_cache(b, self.max_len)
            last, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)}, cache)
            out = np.zeros((b, max_new), np.int32)
            tok = sample(last)
            for t in range(max_new):
                out[:, t] = np.asarray(tok)
                positions = jnp.full((b, 1), s + t, jnp.int32)
                last, cache = self._decode(
                    self.params, cache, tok[:, None].astype(jnp.int32), positions
                )
                tok = sample(last)

        dt = time.perf_counter() - t0
        for i, r in enumerate(requests):
            r.out_tokens = out[i, : r.max_new_tokens]
            r.latency_s = dt
        return requests

    def throughput_stats(self, requests: List[Request]) -> Dict[str, float]:
        """Aggregate a completed batch: request/token counts, wall time,
        tokens/s (batch-level, since latency is shared)."""
        n_new = sum(r.max_new_tokens for r in requests)
        dt = max(r.latency_s for r in requests)
        return {
            "requests": len(requests),
            "new_tokens": n_new,
            "wall_s": dt,
            "tokens_per_s": n_new / dt if dt else 0.0,
        }
