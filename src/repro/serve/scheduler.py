"""Request admission for continuous batching: FCFS queue + arrival processes.

The scheduler is pure host-side bookkeeping.  It owns the waiting line, the
``ContinuousEngine`` owns the slots: between decode steps the engine asks
``admit(now, free_slots)`` and the scheduler hands back at most
``max_prefills_per_step`` arrived requests (prefill/decode interleaving — a
prefill stalls every running slot for one step, so admission is throttled to
bound the latency hit on in-flight decodes).

Reliability contract (the serving twin of the training fault-tolerance
layer):

* every ``admit`` call **sweeps** the arrived backlog first — deadline and
  latency-budget expirations are removed whether or not a slot is free, so
  queue depth (and the ``queue_depth`` telemetry counter) stays honest under
  saturation instead of hiding an unbounded line of corpses behind a busy
  pool;
* with ``max_queue`` / ``max_queue_tokens`` set, the arrived backlog is
  **bounded**: arrivals beyond the bound are shed newest-first (FCFS is
  preserved among the requests that stay) with a typed
  ``RequestStatus.SHED`` / ``shed_reason="queue_full"`` result — overload
  degrades into explicit rejections, never silent queue growth.  The
  legacy unbounded behaviour remains the default (no bounds set).

Every request ends in exactly one terminal :class:`RequestStatus`
(``COMPLETED`` / ``SHED`` / ``TIMED_OUT`` / ``FAILED``); the engine asserts
the counts are disjoint and sum to the submitted total.

Arrival processes for benchmarking: ``poisson_arrivals`` (open-loop load at
a given request rate) and ``trace_arrivals`` (replay explicit timestamps).
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np


class RequestStatus(str, enum.Enum):
    """Typed request lifecycle.  The four terminal states are disjoint:

    * ``COMPLETED`` — generated to EOS / ``max_new_tokens``;
    * ``SHED`` — rejected by admission control (``shed_reason`` says why:
      ``queue_full``, ``deadline``, ``drain``) before holding a slot to
      completion;
    * ``TIMED_OUT`` — exceeded its per-request ``timeout_s`` latency budget
      (in queue or mid-decode — a running request frees its slot at once);
    * ``FAILED`` — transient-failure retries exhausted (``fail_reason``
      carries the last fault kind); surfaced, never silently dropped.
    """

    PENDING = "pending"
    RUNNING = "running"
    COMPLETED = "completed"
    SHED = "shed"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


TERMINAL_STATUSES = frozenset({
    RequestStatus.COMPLETED,
    RequestStatus.SHED,
    RequestStatus.TIMED_OUT,
    RequestStatus.FAILED,
})


@dataclasses.dataclass
class ServeRequest:
    """One generation request plus its lifecycle record."""

    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                       # 0 = disabled
    eos_token: Optional[int] = None
    arrival_s: float = 0.0               # clock time the request arrives
    deadline_s: Optional[float] = None   # max queue wait before shed (rel.)
    timeout_s: Optional[float] = None    # total latency budget before
    #                                      timeout (rel. to arrival)
    rid: int = -1

    # lifecycle (filled by the scheduler/engine)
    submitted_s: float = math.nan        # first submission (retries move
    #                                      arrival_s; this never moves)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_s: float = math.nan
    first_token_s: float = math.nan
    finish_s: float = math.nan
    status: RequestStatus = RequestStatus.PENDING
    shed_reason: Optional[str] = None    # queue_full | deadline | drain
    fail_reason: Optional[str] = None    # last fault kind on FAILED
    attempts: int = 0                    # admissions so far (retries + 1)

    @property
    def dropped(self) -> bool:
        """Back-compat view: True when the request never completed because
        the serving layer gave up on it (shed or timed out)."""
        return self.status in (RequestStatus.SHED, RequestStatus.TIMED_OUT)

    @property
    def born_s(self) -> float:
        """The request's true start: first submission when known (a retry
        re-stamps ``arrival_s`` to re-enter the FCFS queue), else arrival."""
        return self.arrival_s if math.isnan(self.submitted_s) else self.submitted_s

    @property
    def ttft_s(self) -> float:
        """Time to first token, from the original arrival."""
        return self.first_token_s - self.born_s

    @property
    def latency_s(self) -> float:
        """Total latency, from the original arrival to completion."""
        return self.finish_s - self.born_s


def request_tokens(req: ServeRequest) -> int:
    """Admission-control token-budget estimate: prompt plus the full
    generation budget (worst case — EOS may finish a request early)."""
    return len(req.prompt) + int(req.max_new_tokens)


class FCFSScheduler:
    """First-come-first-served admission with deadline sweeps and bounded-
    queue load shedding.

    Args: ``max_prefills_per_step`` throttles admissions per decode step;
    ``max_queue`` / ``max_queue_tokens`` bound the *arrived* backlog (count
    and estimated prompt+generation tokens) — with either set, arrivals
    beyond the bound are shed newest-first at the next sweep.  ``None``
    (default) keeps the legacy unbounded queue.
    """

    def __init__(self, max_prefills_per_step: int = 2, *,
                 max_queue: Optional[int] = None,
                 max_queue_tokens: Optional[int] = None):
        if max_prefills_per_step < 1:
            raise ValueError("max_prefills_per_step must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        if max_queue_tokens is not None and max_queue_tokens < 1:
            raise ValueError("max_queue_tokens must be >= 1 (or None)")
        self.max_prefills_per_step = max_prefills_per_step
        self.max_queue = max_queue
        self.max_queue_tokens = max_queue_tokens
        self._queue: List[ServeRequest] = []
        # arrival keys, kept parallel to _queue: queue_depth runs between
        # every decode step, so it must not rebuild a key list per call
        self._keys: List[Tuple[float, int]] = []
        self._next_rid = 0

    def submit(self, req: ServeRequest) -> ServeRequest:
        """Enqueue a request (assigning a rid if unset) and return it.

        Invariant: the queue stays sorted by (arrival_s, rid) — FCFS even
        when requests are submitted out of arrival order.  Queue bounds are
        enforced at *arrival* (the next ``admit`` sweep), not here: under a
        virtual clock a request may be submitted long before it arrives.
        """
        if req.rid < 0:
            req.rid = self._next_rid
            self._next_rid += 1
        key = (req.arrival_s, req.rid)
        idx = bisect.bisect_left(self._keys, key)
        self._keys.insert(idx, key)
        self._queue.insert(idx, req)
        return req

    def _pop_at(self, idx: int) -> ServeRequest:
        self._keys.pop(idx)
        return self._queue.pop(idx)

    def has_pending(self) -> bool:
        """True while any request is still waiting (arrived or future)."""
        return bool(self._queue)

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival time among queued requests (None if empty)."""
        return self._queue[0].arrival_s if self._queue else None

    def queue_depth(self, now: float) -> int:
        """Requests that have *arrived* and are waiting for a slot at
        ``now`` (the telemetry counter — future arrivals don't count as
        queueing delay)."""
        return bisect.bisect_right(self._keys, (now, float("inf")))

    def sweep(self, now: float) -> List[ServeRequest]:
        """Remove every arrived request the serving layer must give up on,
        independent of slot availability:

        1. **expirations** — queue wait past ``deadline_s`` (→ ``SHED``,
           reason ``deadline``) or total latency budget ``timeout_s``
           already spent in the queue (→ ``TIMED_OUT``);
        2. **overload shedding** — with ``max_queue``/``max_queue_tokens``
           set, the newest arrivals beyond the bound (→ ``SHED``, reason
           ``queue_full``); the oldest keep their place, so FCFS order is
           preserved among surviving (and eventually admitted) requests.

        Returns the removed requests with their terminal status set.
        ``admit`` calls this on every invocation — expired requests leave
        the queue even when zero slots are free.
        """
        removed: List[ServeRequest] = []
        arrived = self.queue_depth(now)
        # 1. expirations, oldest first
        i = 0
        while i < arrived:
            req = self._queue[i]
            waited = now - req.arrival_s
            # the latency budget spans the whole lifetime (retries included);
            # the queue-wait deadline is per attempt
            if req.timeout_s is not None and now - req.born_s > req.timeout_s:
                req.status = RequestStatus.TIMED_OUT
                req.finish_s = now
                removed.append(self._pop_at(i))
                arrived -= 1
            elif req.deadline_s is not None and waited > req.deadline_s:
                req.status = RequestStatus.SHED
                req.shed_reason = "deadline"
                req.finish_s = now
                removed.append(self._pop_at(i))
                arrived -= 1
            else:
                i += 1
        # 2. overload shedding, newest arrivals first
        if self.max_queue is not None or self.max_queue_tokens is not None:
            cap = self.max_queue if self.max_queue is not None else arrived
            keep = min(arrived, cap)
            if self.max_queue_tokens is not None:
                budget = self.max_queue_tokens
                fit = 0
                for req in self._queue[:keep]:
                    budget -= request_tokens(req)
                    if budget < 0:
                        break
                    fit += 1
                keep = fit
            for i in range(arrived - 1, keep - 1, -1):
                req = self._queue[i]
                req.status = RequestStatus.SHED
                req.shed_reason = "queue_full"
                req.finish_s = now
                removed.append(self._pop_at(i))
        return removed

    def drain(self, now: float) -> List[ServeRequest]:
        """Shed the *entire* queue (arrived and future arrivals alike) with
        reason ``drain`` — graceful-shutdown admission stop."""
        removed = []
        while self._queue:
            req = self._pop_at(0)
            req.status = RequestStatus.SHED
            req.shed_reason = "drain"
            req.finish_s = now
            removed.append(req)
        return removed

    def admit(
        self, now: float, free_slots: int
    ) -> Tuple[List[ServeRequest], List[ServeRequest]]:
        """Sweep, then pop up to min(free_slots, max_prefills_per_step)
        arrived requests in FCFS order.  Returns ``(admitted, removed)`` —
        removed requests expired or were shed by the sweep (their terminal
        ``status`` says which) and are *not* scheduled.  The sweep runs on
        every call, so expirations never pile up behind a saturated pool.
        """
        removed = self.sweep(now)
        admitted: List[ServeRequest] = []
        budget = min(free_slots, self.max_prefills_per_step)
        while (budget > 0 and self._queue
               and self._queue[0].arrival_s <= now):
            head = self._pop_at(0)
            head.admitted_s = now
            head.status = RequestStatus.RUNNING
            admitted.append(head)
            budget -= 1
        return admitted, removed


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def poisson_arrivals(
    n: int, rate: float, *, seed: int = 0, start: float = 0.0
) -> np.ndarray:
    """n arrival times from a Poisson process at `rate` req/s.

    ``rate <= 0`` means all requests arrive at `start` (closed batch)."""
    if rate <= 0:
        return np.full(n, start)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return start + np.cumsum(gaps) - gaps[0]  # first request arrives at start


def trace_arrivals(times: Sequence[float]) -> np.ndarray:
    """Replay explicit arrival timestamps (sorted)."""
    return np.sort(np.asarray(times, np.float64))


def assign_arrivals(
    requests: Sequence[ServeRequest], times: np.ndarray
) -> List[ServeRequest]:
    """Stamp one arrival time per request (in order).  Returns the list;
    raises ValueError on a length mismatch."""
    if len(requests) != len(times):
        raise ValueError("one arrival time per request")
    for r, t in zip(requests, times):
        r.arrival_s = float(t)
    return list(requests)
