"""Request admission for continuous batching: FCFS queue + arrival processes.

The scheduler is pure host-side bookkeeping.  It owns the waiting line, the
``ContinuousEngine`` owns the slots: between decode steps the engine asks
``admit(now, free_slots)`` and the scheduler hands back at most
``max_prefills_per_step`` arrived requests (prefill/decode interleaving — a
prefill stalls every running slot for one step, so admission is throttled to
bound the latency hit on in-flight decodes), dropping any whose admission
deadline already passed.

Arrival processes for benchmarking: ``poisson_arrivals`` (open-loop load at a
given request rate) and ``trace_arrivals`` (replay explicit timestamps).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ServeRequest:
    """One generation request plus its lifecycle record."""

    prompt: np.ndarray                   # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                       # 0 = disabled
    eos_token: Optional[int] = None
    arrival_s: float = 0.0               # clock time the request arrives
    deadline_s: Optional[float] = None   # max queue wait before drop (rel.)
    rid: int = -1

    # lifecycle (filled by the engine)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_s: float = math.nan
    first_token_s: float = math.nan
    finish_s: float = math.nan
    dropped: bool = False

    @property
    def ttft_s(self) -> float:
        """Time to first token, from arrival."""
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        """Total latency, from arrival to completion."""
        return self.finish_s - self.arrival_s


class FCFSScheduler:
    """First-come-first-served admission with deadline drops."""

    def __init__(self, max_prefills_per_step: int = 2):
        if max_prefills_per_step < 1:
            raise ValueError("max_prefills_per_step must be >= 1")
        self.max_prefills_per_step = max_prefills_per_step
        self._queue: List[ServeRequest] = []
        # arrival keys, kept parallel to _queue: queue_depth runs between
        # every decode step, so it must not rebuild a key list per call
        self._keys: List[Tuple[float, int]] = []
        self._next_rid = 0

    def submit(self, req: ServeRequest) -> ServeRequest:
        """Enqueue a request (assigning a rid if unset) and return it.

        Invariant: the queue stays sorted by (arrival_s, rid) — FCFS even
        when requests are submitted out of arrival order.
        """
        if req.rid < 0:
            req.rid = self._next_rid
            self._next_rid += 1
        key = (req.arrival_s, req.rid)
        idx = bisect.bisect_left(self._keys, key)
        self._keys.insert(idx, key)
        self._queue.insert(idx, req)
        return req

    def _pop_head(self) -> ServeRequest:
        self._keys.pop(0)
        return self._queue.pop(0)

    def has_pending(self) -> bool:
        """True while any request is still waiting (arrived or future)."""
        return bool(self._queue)

    def next_arrival(self) -> Optional[float]:
        """Earliest arrival time among queued requests (None if empty)."""
        return self._queue[0].arrival_s if self._queue else None

    def queue_depth(self, now: float) -> int:
        """Requests that have *arrived* and are waiting for a slot at
        ``now`` (the telemetry counter — future arrivals don't count as
        queueing delay)."""
        return bisect.bisect_right(self._keys, (now, float("inf")))

    def admit(
        self, now: float, free_slots: int
    ) -> Tuple[List[ServeRequest], List[ServeRequest]]:
        """Pop up to min(free_slots, max_prefills_per_step) arrived requests
        in FCFS order.  Returns (admitted, dropped) — dropped requests sat in
        the queue past their deadline and are marked, not scheduled."""
        admitted: List[ServeRequest] = []
        dropped: List[ServeRequest] = []
        budget = min(free_slots, self.max_prefills_per_step)
        while self._queue and self._queue[0].arrival_s <= now:
            head = self._queue[0]
            if (head.deadline_s is not None
                    and now > head.arrival_s + head.deadline_s):
                head.dropped = True
                dropped.append(self._pop_head())
                continue
            if budget <= 0:
                break
            head.admitted_s = now
            admitted.append(self._pop_head())
            budget -= 1
        return admitted, dropped


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def poisson_arrivals(
    n: int, rate: float, *, seed: int = 0, start: float = 0.0
) -> np.ndarray:
    """n arrival times from a Poisson process at `rate` req/s.

    ``rate <= 0`` means all requests arrive at `start` (closed batch)."""
    if rate <= 0:
        return np.full(n, start)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return start + np.cumsum(gaps) - gaps[0]  # first request arrives at start


def trace_arrivals(times: Sequence[float]) -> np.ndarray:
    """Replay explicit arrival timestamps (sorted)."""
    return np.sort(np.asarray(times, np.float64))


def assign_arrivals(
    requests: Sequence[ServeRequest], times: np.ndarray
) -> List[ServeRequest]:
    """Stamp one arrival time per request (in order).  Returns the list;
    raises ValueError on a length mismatch."""
    if len(requests) != len(times):
        raise ValueError("one arrival time per request")
    for r, t in zip(requests, times):
        r.arrival_s = float(t)
    return list(requests)
