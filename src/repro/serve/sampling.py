"""Vectorized per-request sampling over a batch of next-token logits.

Every row of the batch carries its own sampling parameters (temperature,
top-k), so a continuous-batching step — where each slot belongs to a
different request — samples all slots in one fused op.  ``temperature <= 0``
selects greedy argmax for that row regardless of the rng, which keeps greedy
rows bit-deterministic inside a mixed batch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def top_k_mask(logits: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Mask logits outside each row's top-k to NEG_INF.

    logits: (B, V); k: (B,) int32 — ``k <= 0`` disables the filter for that
    row (equivalent to k = V).  jit-stable: per-row k is a threshold gather,
    not a shape.
    """
    v = logits.shape[-1]
    desc = jnp.sort(logits, axis=-1)[:, ::-1]  # (B, V) descending
    kk = jnp.clip(jnp.where(k <= 0, v, k), 1, v).astype(jnp.int32)
    thresh = jnp.take_along_axis(desc, (kk - 1)[:, None], axis=-1)  # (B, 1)
    return jnp.where(logits >= thresh, logits, NEG_INF)


def sample_tokens(
    rng: jax.Array,
    logits: jnp.ndarray,
    temperature: jnp.ndarray,
    top_k: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Per-row sampling: (B, V) logits → (B,) int32 tokens.

    temperature: (B,) float — rows with ``t <= 0`` take argmax (greedy).
    top_k:       (B,) int32 or None — per-row top-k filter (0 = off).
    """
    logits = logits.astype(jnp.float32)
    temperature = jnp.asarray(temperature, jnp.float32)
    if temperature.ndim == 0:
        temperature = jnp.broadcast_to(temperature, logits.shape[:1])
    greedy = jnp.argmax(logits, axis=-1)

    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    if top_k is not None:
        scaled = top_k_mask(scaled, jnp.asarray(top_k, jnp.int32))
    sampled = jax.random.categorical(rng, scaled, axis=-1)
    return jnp.where(temperature <= 0.0, greedy, sampled).astype(jnp.int32)
