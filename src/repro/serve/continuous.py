"""Continuous-batching engine: a fixed-shape decode step over a slot pool.

The decode batch never drains: one jit'd single-token step runs over all
``n_slots`` slots every iteration, and between steps finished requests are
evicted and queued ones prefilled into the freed slots.  The decode step's
shapes are fixed at (n_slots, 1), so slot churn never recompiles; prefill
compiles once per distinct prompt length (exact-length prefill keeps the
recurrent families — mamba/xLSTM state — exact, where padded prefill would
corrupt the state with pad tokens).

Per-slot sampling parameters ride in (B,) arrays through
``sampling.sample_tokens``; per-slot termination (EOS / stop tokens /
max_new_tokens) is checked on the host between steps.

The engine's clock is wall time plus a fast-forward offset: when all slots
are idle and the next arrival is in the future, the clock jumps there — so a
simulated Poisson trace runs at full speed while latencies stay consistent.

Reliability layer (the serving twin of the training fault-tolerance stack):

* **admission control / load shedding** lives in the scheduler (bounded
  queue + eager expiration sweeps); the engine turns every removal into a
  typed terminal state and telemetry event;
* **per-request timeouts** — a running request past its ``timeout_s``
  latency budget is evicted at the next step boundary (the same granularity
  training uses for preemption), freeing its slot immediately;
* **stall watchdog** — a decode step blowing past ``stall_slo_s`` flips the
  engine into degraded mode: new admissions get their ``max_new_tokens``
  capped and a ``serve_degraded`` event fires; sustained healthy steps
  recover;
* **transient-failure retries** — a :class:`~repro.serve.faults.
  ServeFaultInjector` (or a real detector) reports a non-finite sample or
  corrupted slot; the slot is freed (or quarantined for a cool-down), the
  request requeued with a bounded retry/backoff budget, and exhausted
  budgets surface as ``FAILED`` — never a silent drop;
* **graceful drain** — ``should_drain`` (e.g. a SIGTERM flag) stops
  admissions, sheds the queue, lets in-flight work finish within
  ``drain_grace_s`` and sheds the rest at expiry.

Every submitted request ends in exactly one terminal
:class:`~repro.serve.scheduler.RequestStatus`; ``generate`` asserts the
four terminal counts are disjoint and sum to the submitted total.

Determinism caveat: greedy outputs match the static ``Engine`` token-for-token
on every row-independent family (dense/GQA/SWA, MLA, mamba/hybrid, xLSTM).
Capacity-factor MoE couples rows — per-expert capacity and drop order depend
on the whole batch's token count — so MoE outputs legitimately vary with
batch composition under *any* batching scheme, including the static engine.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.faults import ServeFaultInjector
from repro.serve.kv_pool import KVPool, reset_inactive
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import (
    TERMINAL_STATUSES,
    FCFSScheduler,
    RequestStatus,
    ServeRequest,
)
from repro.sharding.context import ShardCtx, use_sharding
from repro.telemetry import EventLog

TokenCallback = Callable[[ServeRequest, int], None]


def make_pool_prefill(model: Model, max_len: int):
    """(params, tokens(1, S)) → (last-token logits (1, V), batch-1 cache).

    The cache is built at the pool's max_len so insertion into the pool is a
    single fixed-shape dynamic_update_slice per leaf.
    """

    def prefill(params, tokens):
        cache = model.make_cache(1, max_len)
        logits, cache = model.prefill(params, {"tokens": tokens}, cache)
        return logits[:, -1], cache

    return prefill


def make_pool_decode_step(model: Model, *, greedy: bool = False):
    """One continuous-batching step over every slot.

    tokens/positions/temps/top_k are (B,) per-slot arrays; `active` masks
    empty slots — their sampled token is forced to 0, and their cache index
    and position are clamped back to 0 so idle slots never advance.  All
    per-slot arrays live on device between steps (the engine only uploads
    them after slot churn), and the step's rng is ``fold_in(base, step_no)``
    so the hot loop issues no host-side key splits.

    ``greedy=True`` compiles an argmax-only variant (no rng / top-k sort);
    the engine dispatches it whenever every active slot has temperature 0.
    """

    def step(params, cache, tokens, positions, active, temps, top_k,
             base_rng, step_no):
        logits, cache = model.decode(
            params, {"tokens": tokens[:, None]}, cache, positions[:, None]
        )
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = sample_tokens(jax.random.fold_in(base_rng, step_no), last,
                                temps, top_k)
        nxt = jnp.where(active, nxt, 0)
        cache = reset_inactive(cache, active)
        new_pos = jnp.where(active, positions + 1, 0)
        return nxt, new_pos, cache

    return step


class ContinuousEngine:
    """Slot-pool generation engine with mid-decode admission.

    Args: ``n_slots`` bounds the concurrent decode batch; ``max_len`` the
    per-slot cache; ``scheduler`` defaults to FCFS (pass one with
    ``max_queue``/``max_queue_tokens`` for admission control).  Reliability
    knobs: ``faults`` (deterministic :class:`ServeFaultInjector` harness),
    ``max_retries`` / ``retry_backoff_s`` (transient-failure budget),
    ``quarantine_steps`` (decode steps a corrupted slot sits out),
    ``stall_slo_s`` (per-step SLO arming the stall watchdog),
    ``degrade_max_new_tokens`` (admission cap while degraded) and
    ``degrade_recovery_steps`` (healthy steps before recovery).

    Use ``submit`` + ``generate`` (or just ``generate(requests)``).
    Invariant: the decode step shape is pinned to (n_slots, 1) for the
    engine's lifetime — slot churn, admissions and finishes never trigger
    recompilation.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        shard_ctx: Optional[ShardCtx] = None,
        seed: int = 0,
        scheduler: Optional[FCFSScheduler] = None,
        telemetry: Optional[EventLog] = None,
        faults: Optional[ServeFaultInjector] = None,
        max_retries: int = 2,
        retry_backoff_s: float = 0.0,
        quarantine_steps: int = 8,
        stall_slo_s: Optional[float] = None,
        degrade_max_new_tokens: int = 8,
        degrade_recovery_steps: int = 16,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.shard_ctx = shard_ctx
        self.rng = jax.random.key(seed)
        self.scheduler = scheduler or FCFSScheduler()
        # telemetry: per-request lifecycle + per-generate aggregate counters
        # through the unified EventLog; null sink (no-op) by default
        self.telemetry = telemetry if telemetry is not None else EventLog()
        self.faults = faults
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine_steps = quarantine_steps
        self.stall_slo_s = stall_slo_s
        self.degrade_max_new_tokens = degrade_max_new_tokens
        self.degrade_recovery_steps = degrade_recovery_steps
        self.pool = KVPool(model, n_slots, max_len)
        self._prefill = jax.jit(make_pool_prefill(model, max_len))
        self._decode_sample = jax.jit(
            make_pool_decode_step(model), donate_argnums=(1,)
        )
        self._decode_greedy = jax.jit(
            make_pool_decode_step(model, greedy=True), donate_argnums=(1,)
        )
        # per-slot host mirrors; device copies are refreshed lazily (only
        # after slot churn) so steady-state steps upload nothing
        self._slot_req: Dict[int, ServeRequest] = {}
        self._tokens = np.zeros(n_slots, np.int32)
        self._temps = np.zeros(n_slots, np.float32)
        self._top_k = np.zeros(n_slots, np.int32)
        self._dev: Optional[tuple] = None  # (tokens, positions, active, temps, top_k)
        self._step_no = 0
        # reliability bookkeeping
        self._roster: List[ServeRequest] = []   # every submission since
        #                                         the last generate() drain
        self._quarantined: Dict[int, int] = {}  # slot -> release step
        self._degraded = False
        self._healthy_steps = 0
        self._run_steps = 0        # decode steps this generate (fault keying)
        self._n_retries = 0
        self._n_quarantines = 0

    # ---- internals -------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def _device_state(self) -> tuple:
        if self._dev is None:
            self._dev = (
                jnp.asarray(self._tokens),
                jnp.asarray(self.pool.lengths),
                jnp.asarray(self.pool.active_mask),
                jnp.asarray(self._temps),
                jnp.asarray(self._top_k),
            )
        return self._dev

    def _finished(self, req: ServeRequest, tok: int) -> bool:
        if req.eos_token is not None and tok == req.eos_token:
            return True
        return len(req.out_tokens) >= req.max_new_tokens

    def _emit_terminal(self, req: ServeRequest) -> None:
        """One ``serve_request`` event per terminal request — the lifecycle
        record the RunReport folds."""
        fields = dict(
            rid=req.rid, status=req.status.value, dropped=req.dropped,
            prompt_len=len(req.prompt), new_tokens=len(req.out_tokens),
            arrival_s=req.born_s, attempts=req.attempts,
        )
        if req.shed_reason is not None:
            fields["reason"] = req.shed_reason
        if req.fail_reason is not None:
            fields["reason"] = req.fail_reason
        if math.isfinite(req.first_token_s):
            fields["ttft_s"] = req.ttft_s
        if math.isfinite(req.finish_s) and req.status is RequestStatus.COMPLETED:
            fields["latency_s"] = req.latency_s
        self.telemetry.emit("serve_request", **fields)

    def _terminal_removed(self, req: ServeRequest) -> None:
        """Emit the typed lifecycle event for a request the scheduler swept
        (shed or timed out in the queue) plus its terminal record."""
        if req.status is RequestStatus.TIMED_OUT:
            self.telemetry.emit("serve_timeout", rid=req.rid, where="queue")
        else:
            self.telemetry.emit("serve_shed", rid=req.rid,
                                reason=req.shed_reason or "unknown")
        self._emit_terminal(req)

    def _finish(self, slot: int, now: float) -> None:
        req = self._slot_req.pop(slot)
        req.finish_s = now
        req.status = RequestStatus.COMPLETED
        self.pool.evict(slot)
        self._dev = None  # slot churn: device per-slot state is stale
        self._emit_terminal(req)

    def _timeout_slot(self, slot: int, now: float) -> None:
        """A running request blew its latency budget: free the slot now."""
        req = self._slot_req.pop(slot)
        req.finish_s = now
        req.status = RequestStatus.TIMED_OUT
        self.pool.evict(slot)
        self._dev = None
        self.telemetry.emit("serve_timeout", rid=req.rid, where="decode",
                            new_tokens=len(req.out_tokens))
        self._emit_terminal(req)

    def _shed_slot(self, slot: int, now: float, reason: str) -> None:
        req = self._slot_req.pop(slot)
        req.finish_s = now
        req.status = RequestStatus.SHED
        req.shed_reason = reason
        self.pool.evict(slot)
        self._dev = None
        self.telemetry.emit("serve_shed", rid=req.rid, reason=reason)
        self._emit_terminal(req)

    def _transient_failure(self, req: ServeRequest, slot: int, kind: str,
                           now: float) -> None:
        """A detected transient fault (non-finite sample / corrupted slot):
        quarantine or free the slot, then retry or fail the request."""
        self._slot_req.pop(slot, None)
        if kind == "slot_corrupt":
            self.pool.quarantine(slot)
            self._quarantined[slot] = self._run_steps + self.quarantine_steps
            self._n_quarantines += 1
            self.telemetry.emit("serve_quarantine", slot=slot, rid=req.rid,
                                release_step=self._quarantined[slot])
        else:
            self.pool.evict(slot)
        self._dev = None
        if req.attempts > self.max_retries:
            req.status = RequestStatus.FAILED
            req.fail_reason = kind
            req.finish_s = now
            self._emit_terminal(req)
            return
        self._n_retries += 1
        backoff = self.retry_backoff_s * req.attempts
        self.telemetry.emit("serve_retry", rid=req.rid,
                            attempt=req.attempts, reason=kind,
                            backoff_s=backoff)
        req.out_tokens = []
        req.admitted_s = math.nan
        req.first_token_s = math.nan
        req.status = RequestStatus.PENDING
        req.arrival_s = now + backoff
        self.scheduler.submit(req)

    def _admit_one(
        self, req: ServeRequest, clock: Callable[[], float],
        on_token: Optional[TokenCallback],
    ) -> None:
        req.attempts += 1
        if self._degraded:
            # degraded mode: cap the generation budget of new admissions so
            # a stalling backend sheds decode work before it sheds requests
            req.max_new_tokens = max(
                1, min(req.max_new_tokens, self.degrade_max_new_tokens))
        slot = self.pool.acquire()
        assert slot is not None, "admit() respects free-slot budget"
        prompt = np.asarray(req.prompt, np.int32)
        last, cache1 = self._prefill(self.params, jnp.asarray(prompt[None]))
        tok = int(
            sample_tokens(
                self._next_key(), last,
                jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), req.top_k, jnp.int32),
            )[0]
        )
        self.pool.insert(cache1, slot, len(prompt))
        self._dev = None  # slot churn: device per-slot state is stale
        # fault-injection point: the first sample of this attempt.  A real
        # detector would check np.isnan(logits) / cache health here.
        kind = (self.faults.fire_request(req.rid)
                if self.faults is not None else None)
        if kind is not None:
            self._transient_failure(req, slot, kind, clock())
            return
        req.out_tokens.append(tok)
        # the int() above blocked on the prefill: stamp after, not before
        req.first_token_s = clock()
        if on_token is not None:
            on_token(req, tok)
        if self._finished(req, tok):
            self._slot_req[slot] = req
            self._finish(slot, req.first_token_s)
            return
        self._slot_req[slot] = req
        self._tokens[slot] = tok
        self._temps[slot] = req.temperature
        self._top_k[slot] = req.top_k

    def _release_quarantined(self, *, force: bool = False) -> None:
        for slot, due in list(self._quarantined.items()):
            if force or self._run_steps >= due:
                self.pool.release(slot)
                del self._quarantined[slot]

    def _watchdog(self, step_wall_s: float) -> None:
        """Stall watchdog: one slow decode step degrades admissions; a
        sustained healthy streak recovers."""
        if self.stall_slo_s is None:
            return
        if step_wall_s > self.stall_slo_s:
            self._healthy_steps = 0
            if not self._degraded:
                self._degraded = True
                self.telemetry.emit(
                    "serve_degraded", active=True, step_s=step_wall_s,
                    slo_s=self.stall_slo_s,
                    max_new_tokens_cap=self.degrade_max_new_tokens)
        elif self._degraded:
            self._healthy_steps += 1
            if self._healthy_steps >= self.degrade_recovery_steps:
                self._degraded = False
                self._healthy_steps = 0
                self.telemetry.emit("serve_degraded", active=False,
                                    step_s=step_wall_s,
                                    slo_s=self.stall_slo_s)

    def _step(
        self, clock: Callable[[], float], on_token: Optional[TokenCallback]
    ) -> None:
        active = self.pool.active_mask.copy()
        tokens_d, pos_d, active_d, temps_d, topk_d = self._device_state()
        decode = (
            self._decode_greedy
            if float(self._temps[active].max(initial=0.0)) <= 0.0
            else self._decode_sample
        )
        toks_d, pos_d, self.pool.cache = decode(
            self.params, self.pool.cache, tokens_d, pos_d, active_d,
            temps_d, topk_d, self.rng, np.int32(self._step_no),
        )
        self._step_no += 1
        toks = np.asarray(toks_d)  # the loop's one device→host sync
        now = clock()  # after the sync: timestamps include the step's work
        self.pool.lengths[active] += 1
        self._tokens[active] = toks[active]
        # feed the sampled tokens straight back; invalidated on churn below
        self._dev = (toks_d, pos_d, active_d, temps_d, topk_d)
        for slot in list(self._slot_req):
            req = self._slot_req[slot]
            tok = int(toks[slot])
            req.out_tokens.append(tok)
            if on_token is not None:
                on_token(req, tok)
            if self._finished(req, tok):
                self._finish(slot, now)

    # ---- public API ------------------------------------------------------
    def submit(self, req: ServeRequest) -> ServeRequest:
        """Validate and enqueue a request (returns it for chaining).

        Invariant: admission is deferred to ``generate``'s loop — a
        submitted request holds no slot until the scheduler admits it, and
        overload rejection happens at *arrival* (the scheduler's bounded-
        queue sweep), so check ``req.status`` after ``generate``.  Raises
        ValueError if the prompt is empty, the prompt+budget cannot fit the
        pool's ``max_len``, or the sampling params are malformed
        (non-finite/negative temperature, negative top_k) — caught here so
        a bad request fails loudly at submit instead of poisoning the
        batched sampling arrays mid-decode.
        """
        if len(req.prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "always samples one token)")
        if not math.isfinite(req.temperature) or req.temperature < 0:
            raise ValueError(
                f"temperature must be finite and >= 0, got {req.temperature}"
            )
        if req.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {req.top_k}")
        # the last sampled token is returned but never written to the cache
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache positions but pool max_len is "
                f"{self.max_len}"
            )
        if math.isnan(req.submitted_s):
            req.submitted_s = req.arrival_s
        self._roster.append(req)
        return self.scheduler.submit(req)

    def generate(
        self,
        requests: Optional[Sequence[ServeRequest]] = None,
        *,
        on_token: Optional[TokenCallback] = None,
        should_drain: Optional[Callable[[], bool]] = None,
        drain_grace_s: float = 5.0,
    ) -> List[ServeRequest]:
        """Run until the queue and all slots drain.

        Args: ``requests`` to submit up front (may be None if ``submit`` was
        called directly); ``on_token(req, tok)`` streams every sampled
        token; ``should_drain`` is polled once per loop — when it first
        returns True the engine stops admissions, sheds the queue, and
        gives in-flight requests ``drain_grace_s`` seconds to finish before
        shedding them too (SIGTERM wiring lives in ``launch/serve.py``).
        Returns the submitted requests, completed in place — check
        ``.status`` for the terminal state (``.dropped`` still covers the
        shed/timed-out union).  Invariants: wall-clock latencies stay
        consistent even when the virtual clock fast-forwards across idle
        gaps between arrivals, and every request submitted since the last
        ``generate`` ends in exactly one terminal state (asserted).
        """
        submitted = [self.submit(r) for r in requests] if requests else []
        t0 = time.perf_counter()
        offset = 0.0  # virtual fast-forward while idle
        telem = self.telemetry.enabled
        # host-side counters (ints per loop iteration — no device syncs)
        queue_samples: List[int] = []
        occ_samples: List[int] = []
        n_steps = 0
        self._run_steps = 0
        self._n_retries = 0
        self._n_quarantines = 0
        draining = False
        drain_deadline = math.inf

        def clock() -> float:
            return time.perf_counter() - t0 + offset

        with use_sharding(self.shard_ctx):
            while self.scheduler.has_pending() or self._slot_req:
                now = clock()
                if (not draining and should_drain is not None
                        and should_drain()):
                    draining = True
                    drain_deadline = now + max(0.0, drain_grace_s)
                    shed = self.scheduler.drain(now)
                    self.telemetry.emit(
                        "serve_drain", queued=len(shed),
                        in_flight=len(self._slot_req),
                        grace_s=max(0.0, drain_grace_s))
                    for req in shed:
                        self._terminal_removed(req)
                if draining:
                    # retries resubmitted after the drain started are shed
                    for req in self.scheduler.drain(now):
                        self._terminal_removed(req)
                    if now >= drain_deadline and self._slot_req:
                        for slot in list(self._slot_req):
                            self._shed_slot(slot, now, "drain")
                    admitted = []
                else:
                    # running requests past their latency budget free their
                    # slot before this round's admissions claim it
                    for slot in list(self._slot_req):
                        req = self._slot_req[slot]
                        if (req.timeout_s is not None
                                and now - req.born_s > req.timeout_s):
                            self._timeout_slot(slot, now)
                    self._release_quarantined()
                    admitted, removed = self.scheduler.admit(
                        now, self.pool.n_free)
                    for req in removed:
                        self._terminal_removed(req)
                for req in admitted:
                    self._admit_one(req, clock, on_token)
                if telem:
                    queue_samples.append(self.scheduler.queue_depth(now))
                    occ_samples.append(
                        self.n_slots - self.pool.n_free
                        - len(self._quarantined))
                if not self._slot_req:
                    if self._quarantined and self.scheduler.has_pending():
                        # no decode steps will run while the pool idles, so
                        # a quarantine can never expire on its own: release
                        # early rather than deadlock the queue
                        self._release_quarantined(force=True)
                        continue
                    nxt = self.scheduler.next_arrival()
                    if nxt is None:
                        break
                    offset += max(0.0, nxt - clock())
                    continue
                t_step = time.perf_counter()
                if self.faults is not None:
                    stall = self.faults.stall_s(self._run_steps)
                    if stall > 0.0:
                        time.sleep(stall)
                self._step(clock, on_token)
                self._watchdog(time.perf_counter() - t_step)
                self._run_steps += 1
                n_steps += 1
        self._release_quarantined(force=True)

        # exact, disjoint terminal accounting over everything submitted
        # since the last generate (direct submit() calls included)
        roster, self._roster = self._roster, []
        counts = {s: 0 for s in TERMINAL_STATUSES}
        for r in roster:
            if r.status not in counts:
                raise RuntimeError(
                    f"request {r.rid} left generate() non-terminal: "
                    f"{r.status}")
            counts[r.status] += 1
        assert sum(counts.values()) == len(roster)

        if telem:
            stats = serving_stats(roster)
            stats.update(
                decode_steps=n_steps,
                submitted=len(roster),
                retries=self._n_retries,
                quarantines=self._n_quarantines,
                drained=draining,
                degraded=self._degraded,
                queue_depth_mean=float(np.mean(queue_samples)) if queue_samples else 0.0,
                queue_depth_max=int(max(queue_samples, default=0)),
                slot_occupancy_mean=(
                    float(np.mean(occ_samples)) / self.n_slots
                    if occ_samples else 0.0
                ),
                n_slots=self.n_slots,
            )
            self.telemetry.emit("serve_stats", **stats)
        return submitted


def serving_stats(requests: Sequence[ServeRequest]) -> Dict[str, float]:
    """Aggregate throughput/latency over a completed request set.

    Returns the disjoint terminal counts (``completed`` / ``shed`` /
    ``timed_out`` / ``failed``, summing to ``submitted``), request/token
    counts, tokens/s over the busy window, and p50/p99 latency + TTFT.
    Invariants: only completed requests enter the latency percentiles, and
    the legacy ``dropped`` counter equals ``shed + timed_out`` exactly.
    """
    by_status = {s: 0 for s in TERMINAL_STATUSES}
    for r in requests:
        if r.status in by_status:
            by_status[r.status] += 1
    counts = {
        "submitted": len(requests),
        "completed": by_status[RequestStatus.COMPLETED],
        "shed": by_status[RequestStatus.SHED],
        "timed_out": by_status[RequestStatus.TIMED_OUT],
        "failed": by_status[RequestStatus.FAILED],
        "dropped": (by_status[RequestStatus.SHED]
                    + by_status[RequestStatus.TIMED_OUT]),
    }
    done = [r for r in requests
            if r.status is RequestStatus.COMPLETED and r.out_tokens]
    if not done:
        return {"requests": 0, **counts}
    new_tokens = sum(len(r.out_tokens) for r in done)
    start = min(r.born_s for r in done)
    end = max(r.finish_s for r in done)
    lat = np.array([r.latency_s for r in done])
    ttft = np.array([r.ttft_s for r in done])
    wall = max(end - start, 1e-9)
    return {
        "requests": len(done),
        **counts,
        "new_tokens": new_tokens,
        "wall_s": wall,
        "tokens_per_s": new_tokens / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
    }
