"""Continuous-batching engine: a fixed-shape decode step over a slot pool.

The decode batch never drains: one jit'd single-token step runs over all
``n_slots`` slots every iteration, and between steps finished requests are
evicted and queued ones prefilled into the freed slots.  The decode step's
shapes are fixed at (n_slots, 1), so slot churn never recompiles; prefill
compiles once per distinct prompt length (exact-length prefill keeps the
recurrent families — mamba/xLSTM state — exact, where padded prefill would
corrupt the state with pad tokens).

Per-slot sampling parameters ride in (B,) arrays through
``sampling.sample_tokens``; per-slot termination (EOS / stop tokens /
max_new_tokens) is checked on the host between steps.

The engine's clock is wall time plus a fast-forward offset: when all slots
are idle and the next arrival is in the future, the clock jumps there — so a
simulated Poisson trace runs at full speed while latencies stay consistent.

Determinism caveat: greedy outputs match the static ``Engine`` token-for-token
on every row-independent family (dense/GQA/SWA, MLA, mamba/hybrid, xLSTM).
Capacity-factor MoE couples rows — per-expert capacity and drop order depend
on the whole batch's token count — so MoE outputs legitimately vary with
batch composition under *any* batching scheme, including the static engine.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.kv_pool import KVPool, reset_inactive
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import FCFSScheduler, ServeRequest
from repro.sharding.context import ShardCtx, use_sharding
from repro.telemetry import EventLog

TokenCallback = Callable[[ServeRequest, int], None]


def make_pool_prefill(model: Model, max_len: int):
    """(params, tokens(1, S)) → (last-token logits (1, V), batch-1 cache).

    The cache is built at the pool's max_len so insertion into the pool is a
    single fixed-shape dynamic_update_slice per leaf.
    """

    def prefill(params, tokens):
        cache = model.make_cache(1, max_len)
        logits, cache = model.prefill(params, {"tokens": tokens}, cache)
        return logits[:, -1], cache

    return prefill


def make_pool_decode_step(model: Model, *, greedy: bool = False):
    """One continuous-batching step over every slot.

    tokens/positions/temps/top_k are (B,) per-slot arrays; `active` masks
    empty slots — their sampled token is forced to 0, and their cache index
    and position are clamped back to 0 so idle slots never advance.  All
    per-slot arrays live on device between steps (the engine only uploads
    them after slot churn), and the step's rng is ``fold_in(base, step_no)``
    so the hot loop issues no host-side key splits.

    ``greedy=True`` compiles an argmax-only variant (no rng / top-k sort);
    the engine dispatches it whenever every active slot has temperature 0.
    """

    def step(params, cache, tokens, positions, active, temps, top_k,
             base_rng, step_no):
        logits, cache = model.decode(
            params, {"tokens": tokens[:, None]}, cache, positions[:, None]
        )
        last = logits[:, -1]
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            nxt = sample_tokens(jax.random.fold_in(base_rng, step_no), last,
                                temps, top_k)
        nxt = jnp.where(active, nxt, 0)
        cache = reset_inactive(cache, active)
        new_pos = jnp.where(active, positions + 1, 0)
        return nxt, new_pos, cache

    return step


class ContinuousEngine:
    """Slot-pool generation engine with mid-decode admission.

    Args: ``n_slots`` bounds the concurrent decode batch; ``max_len`` the
    per-slot cache; ``scheduler`` defaults to FCFS.  Use ``submit`` +
    ``generate`` (or just ``generate(requests)``).  Invariant: the decode
    step shape is pinned to (n_slots, 1) for the engine's lifetime — slot
    churn, admissions and finishes never trigger recompilation.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        shard_ctx: Optional[ShardCtx] = None,
        seed: int = 0,
        scheduler: Optional[FCFSScheduler] = None,
        telemetry: Optional[EventLog] = None,
    ):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.shard_ctx = shard_ctx
        self.rng = jax.random.key(seed)
        self.scheduler = scheduler or FCFSScheduler()
        # telemetry: per-request lifecycle + per-generate aggregate counters
        # through the unified EventLog; null sink (no-op) by default
        self.telemetry = telemetry if telemetry is not None else EventLog()
        self.pool = KVPool(model, n_slots, max_len)
        self._prefill = jax.jit(make_pool_prefill(model, max_len))
        self._decode_sample = jax.jit(
            make_pool_decode_step(model), donate_argnums=(1,)
        )
        self._decode_greedy = jax.jit(
            make_pool_decode_step(model, greedy=True), donate_argnums=(1,)
        )
        # per-slot host mirrors; device copies are refreshed lazily (only
        # after slot churn) so steady-state steps upload nothing
        self._slot_req: Dict[int, ServeRequest] = {}
        self._tokens = np.zeros(n_slots, np.int32)
        self._temps = np.zeros(n_slots, np.float32)
        self._top_k = np.zeros(n_slots, np.int32)
        self._dev: Optional[tuple] = None  # (tokens, positions, active, temps, top_k)
        self._step_no = 0

    # ---- internals -------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def _device_state(self) -> tuple:
        if self._dev is None:
            self._dev = (
                jnp.asarray(self._tokens),
                jnp.asarray(self.pool.lengths),
                jnp.asarray(self.pool.active_mask),
                jnp.asarray(self._temps),
                jnp.asarray(self._top_k),
            )
        return self._dev

    def _finished(self, req: ServeRequest, tok: int) -> bool:
        if req.eos_token is not None and tok == req.eos_token:
            return True
        return len(req.out_tokens) >= req.max_new_tokens

    def _finish(self, slot: int, now: float) -> None:
        req = self._slot_req.pop(slot)
        req.finish_s = now
        self.pool.evict(slot)
        self._dev = None  # slot churn: device per-slot state is stale
        self.telemetry.emit(
            "serve_request", rid=req.rid, prompt_len=len(req.prompt),
            new_tokens=len(req.out_tokens), arrival_s=req.arrival_s,
            admitted_s=req.admitted_s, ttft_s=req.ttft_s,
            latency_s=req.latency_s, dropped=False,
        )

    def _admit_one(
        self, req: ServeRequest, clock: Callable[[], float],
        on_token: Optional[TokenCallback],
    ) -> None:
        slot = self.pool.acquire()
        assert slot is not None, "admit() respects free-slot budget"
        prompt = np.asarray(req.prompt, np.int32)
        last, cache1 = self._prefill(self.params, jnp.asarray(prompt[None]))
        tok = int(
            sample_tokens(
                self._next_key(), last,
                jnp.full((1,), req.temperature, jnp.float32),
                jnp.full((1,), req.top_k, jnp.int32),
            )[0]
        )
        self.pool.insert(cache1, slot, len(prompt))
        req.out_tokens.append(tok)
        # the int() above blocked on the prefill: stamp after, not before
        req.first_token_s = clock()
        if on_token is not None:
            on_token(req, tok)
        if self._finished(req, tok):
            self._slot_req[slot] = req
            self._finish(slot, req.first_token_s)
            return
        self._slot_req[slot] = req
        self._tokens[slot] = tok
        self._temps[slot] = req.temperature
        self._top_k[slot] = req.top_k
        self._dev = None  # slot churn: device per-slot state is stale

    def _step(
        self, clock: Callable[[], float], on_token: Optional[TokenCallback]
    ) -> None:
        active = self.pool.active_mask.copy()
        tokens_d, pos_d, active_d, temps_d, topk_d = self._device_state()
        decode = (
            self._decode_greedy
            if float(self._temps[active].max(initial=0.0)) <= 0.0
            else self._decode_sample
        )
        toks_d, pos_d, self.pool.cache = decode(
            self.params, self.pool.cache, tokens_d, pos_d, active_d,
            temps_d, topk_d, self.rng, np.int32(self._step_no),
        )
        self._step_no += 1
        toks = np.asarray(toks_d)  # the loop's one device→host sync
        now = clock()  # after the sync: timestamps include the step's work
        self.pool.lengths[active] += 1
        self._tokens[active] = toks[active]
        # feed the sampled tokens straight back; invalidated on churn below
        self._dev = (toks_d, pos_d, active_d, temps_d, topk_d)
        for slot in list(self._slot_req):
            req = self._slot_req[slot]
            tok = int(toks[slot])
            req.out_tokens.append(tok)
            if on_token is not None:
                on_token(req, tok)
            if self._finished(req, tok):
                self._finish(slot, now)

    # ---- public API ------------------------------------------------------
    def submit(self, req: ServeRequest) -> ServeRequest:
        """Validate and enqueue a request (returns it for chaining).

        Invariant: admission is deferred to ``generate``'s loop — a
        submitted request holds no slot until the scheduler admits it.
        Raises ValueError if the prompt is empty, the prompt+budget cannot
        fit the pool's ``max_len``, or the sampling params are malformed
        (non-finite/negative temperature, negative top_k) — caught here so
        a bad request fails loudly at submit instead of poisoning the
        batched sampling arrays mid-decode.
        """
        if len(req.prompt) < 1:
            raise ValueError("prompt must hold at least one token")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1 (the prefill "
                             "always samples one token)")
        if not math.isfinite(req.temperature) or req.temperature < 0:
            raise ValueError(
                f"temperature must be finite and >= 0, got {req.temperature}"
            )
        if req.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {req.top_k}")
        # the last sampled token is returned but never written to the cache
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache positions but pool max_len is "
                f"{self.max_len}"
            )
        return self.scheduler.submit(req)

    def generate(
        self,
        requests: Optional[Sequence[ServeRequest]] = None,
        *,
        on_token: Optional[TokenCallback] = None,
    ) -> List[ServeRequest]:
        """Run until the queue and all slots drain.

        Args: ``requests`` to submit up front (may be None if ``submit`` was
        called directly); ``on_token(req, tok)`` streams every sampled token.
        Returns the submitted requests, completed in place (check
        ``.dropped`` for deadline casualties).  Invariant: wall-clock
        latencies stay consistent even when the virtual clock fast-forwards
        across idle gaps between arrivals.
        """
        submitted = [self.submit(r) for r in requests] if requests else []
        t0 = time.perf_counter()
        offset = 0.0  # virtual fast-forward while idle
        telem = self.telemetry.enabled
        # host-side counters (ints per loop iteration — no device syncs)
        queue_samples: List[int] = []
        occ_samples: List[int] = []
        n_dropped = 0
        n_steps = 0

        def clock() -> float:
            return time.perf_counter() - t0 + offset

        with use_sharding(self.shard_ctx):
            while self.scheduler.has_pending() or self._slot_req:
                now = clock()
                admitted, dropped = self.scheduler.admit(now, self.pool.n_free)
                n_dropped += len(dropped)
                for req in dropped:
                    self.telemetry.emit(
                        "serve_request", rid=req.rid,
                        prompt_len=len(req.prompt), new_tokens=0,
                        arrival_s=req.arrival_s, dropped=True,
                    )
                for req in admitted:
                    self._admit_one(req, clock, on_token)
                if telem:
                    queue_samples.append(self.scheduler.queue_depth(now))
                    occ_samples.append(self.n_slots - self.pool.n_free)
                if not self._slot_req:
                    nxt = self.scheduler.next_arrival()
                    if nxt is None:
                        break
                    offset += max(0.0, nxt - clock())
                    continue
                self._step(clock, on_token)
                n_steps += 1
        if telem:
            stats = serving_stats(submitted)
            stats.update(
                decode_steps=n_steps,
                # serving_stats only sees requests passed to generate();
                # n_dropped also covers requests enqueued via submit()
                dropped=max(n_dropped, int(stats.get("dropped", 0))),
                queue_depth_mean=float(np.mean(queue_samples)) if queue_samples else 0.0,
                queue_depth_max=int(max(queue_samples, default=0)),
                slot_occupancy_mean=(
                    float(np.mean(occ_samples)) / self.n_slots
                    if occ_samples else 0.0
                ),
                n_slots=self.n_slots,
            )
            self.telemetry.emit("serve_stats", **stats)
        return submitted


def serving_stats(requests: Sequence[ServeRequest]) -> Dict[str, float]:
    """Aggregate throughput/latency over a completed request set.

    Returns request/token counts, tokens/s over the busy window, and
    p50/p99 latency + TTFT.  Invariant: dropped requests are counted but
    excluded from every latency percentile.
    """
    done = [r for r in requests if not r.dropped and r.out_tokens]
    if not done:
        return {"requests": 0, "dropped": sum(r.dropped for r in requests)}
    new_tokens = sum(len(r.out_tokens) for r in done)
    start = min(r.arrival_s for r in done)
    end = max(r.finish_s for r in done)
    lat = np.array([r.latency_s for r in done])
    ttft = np.array([r.ttft_s for r in done])
    wall = max(end - start, 1e-9)
    return {
        "requests": len(done),
        "dropped": sum(r.dropped for r in requests),
        "new_tokens": new_tokens,
        "wall_s": wall,
        "tokens_per_s": new_tokens / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
    }
