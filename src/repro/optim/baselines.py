"""Baseline optimizers the paper compares LAMB against (§4, App. H).

sgd / momentum / adam / adamw / adagrad — all built from repro.optim.base
transforms so they share state conventions and sharding behavior with LAMB.
"""
from __future__ import annotations

from typing import Optional

from repro.optim.base import (
    GradientTransformation,
    ScalarOrSchedule,
    add_decayed_weights,
    chain,
    scale_by_adagrad,
    scale_by_adam,
    scale_by_learning_rate,
    trace,
)


def sgd(learning_rate: ScalarOrSchedule) -> GradientTransformation:
    return chain(scale_by_learning_rate(learning_rate))


def momentum(
    learning_rate: ScalarOrSchedule,
    beta: float = 0.9,
    weight_decay: float = 0.0,
    wd_mask=None,
    *,
    average: bool = False,
) -> GradientTransformation:
    """SGD with heavy-ball momentum (Goyal et al. baseline).

    ``average=False`` is the classic accumulator (m = beta*m + g);
    ``average=True`` is the EMA form the paper's LARS pseudocode uses.
    """
    transforms = []
    if weight_decay:
        transforms.append(add_decayed_weights(weight_decay, wd_mask))
    transforms.append(trace(beta, average=average))
    transforms.append(scale_by_learning_rate(learning_rate))
    return chain(*transforms)


def adam(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    l2_regularization: float = 0.0,
) -> GradientTransformation:
    """Adam; optional classic (coupled) L2 added to the gradient."""
    transforms = []
    if l2_regularization:
        transforms.append(add_decayed_weights(l2_regularization, None))
    transforms.append(scale_by_adam(b1, b2, eps))
    transforms.append(scale_by_learning_rate(learning_rate))
    return chain(*transforms)


def adamw(
    learning_rate: ScalarOrSchedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    weight_decay: float = 0.01,
    wd_mask=None,
) -> GradientTransformation:
    """AdamW: decoupled weight decay added to the Adam direction."""
    return chain(
        scale_by_adam(b1, b2, eps),
        add_decayed_weights(weight_decay, wd_mask),
        scale_by_learning_rate(learning_rate),
    )


def adagrad(
    learning_rate: ScalarOrSchedule, eps: float = 1e-7
) -> GradientTransformation:
    return chain(scale_by_adagrad(eps), scale_by_learning_rate(learning_rate))
