"""Minimal optax-style gradient-transformation library.

optax is not available in this environment, and the paper's contribution *is*
an optimizer, so the transformation algebra is built here from scratch:

    GradientTransformation(init, update)
    update(grads, state, params) -> (updates, state)

All transforms are pure pytree functions, compose with ``chain`` and are
pjit-friendly (norm reductions over sharded leaves lower to SPMD all-reduces).

Mixed-precision contract: transforms accept updates of any floating dtype but
do *all* stateful arithmetic and every norm reduction in fp32 — optimizer
moments are fp32 unless a ``moment_dtype`` narrows the stored copy, and
reductions upcast before summing so bf16 gradients cannot overflow or lose
dynamic range inside the optimizer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


class EmptyState(NamedTuple):
    pass


class TraceState(NamedTuple):
    momentum: PyTree


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree


class ScaleByAdagradState(NamedTuple):
    accum: PyTree


class ScheduleState(NamedTuple):
    count: jnp.ndarray


def identity() -> GradientTransformation:
    """The no-op transform: updates pass through unchanged (chain unit)."""
    return GradientTransformation(
        init=lambda params: EmptyState(),
        update=lambda u, s, p=None: (u, s),
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transforms left-to-right into one.

    Args: any number of ``GradientTransformation``s.  Returns one whose state
    is the tuple of member states and whose ``update`` threads the updates
    through each member in order.  Invariant: ``params`` is passed to every
    member unchanged (members see the *pre-step* parameters).
    """

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    """Stateless transform multiplying every update leaf by ``factor``."""
    return GradientTransformation(
        init=lambda params: EmptyState(),
        update=lambda u, s, p=None: (jax.tree.map(lambda x: factor * x, u), s),
    )


def _lr_value(lr: ScalarOrSchedule, count) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr)


def scale_by_learning_rate(
    learning_rate: ScalarOrSchedule, *, flip_sign: bool = True
) -> GradientTransformation:
    """Multiply updates by -lr (lr may be a schedule of the step count).

    Args: ``learning_rate`` as a float or a ``count -> lr`` schedule;
    ``flip_sign=False`` keeps +lr (for optimizers that already negate).
    Returns a transform holding the schedule counter (``ScheduleState``).
    Invariant: the counter starts at 0 — the first step sees ``lr(0)`` — and
    is exactly what stage-2 re-warm-up resets (see train/trainer.py).
    """

    def init(params):
        return ScheduleState(count=jnp.zeros([], jnp.int32))

    def update(updates, state, params=None):
        lr = _lr_value(learning_rate, state.count)
        m = -lr if flip_sign else lr
        updates = jax.tree.map(lambda x: (m * x).astype(x.dtype), updates)
        return updates, ScheduleState(count=state.count + 1)

    return GradientTransformation(init, update)


def trace(decay: float, *, average: bool = True) -> GradientTransformation:
    """Heavy-ball momentum: m = decay*m + (1-decay)*g (paper's LARS form).

    Args: ``decay`` = β1; ``average=False`` drops the (1-decay) factor
    (classical momentum).  Returns a transform whose updates are the new
    momentum.  Invariant: the momentum buffer is fp32 regardless of gradient
    dtype.
    """
    mix = (1.0 - decay) if average else 1.0

    def init(params):
        return TraceState(
            momentum=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        )

    def update(updates, state, params=None):
        new_m = jax.tree.map(
            lambda m, g: decay * m + mix * g.astype(jnp.float32),
            state.momentum,
            updates,
        )
        return new_m, TraceState(momentum=new_m)

    return GradientTransformation(init, update)


def scale_by_adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-6,
    *,
    bias_correction: bool = True,
    nesterov_m: bool = False,
    nesterov_v: bool = False,
    moment_dtype=None,
) -> GradientTransformation:
    """Adam second-moment rescaling; r_t = m̂/(sqrt(v̂)+eps).

    ``bias_correction=False`` implements App. E of the paper (adam-correction
    removed; its effect is equivalent to LR warmup).  ``nesterov_m`` gives the
    N-LAMB first-moment rule (Alg. 3) and ``nesterov_v`` additionally the
    NN-LAMB second-moment rule (Alg. 4), both with constant betas.

    ``moment_dtype`` narrows the *stored* m/v (e.g. bf16 halves optimizer
    state); the EMA arithmetic still runs in fp32 each step.  Invariant:
    returned updates are always fp32, whatever the gradient dtype.
    """

    mdt = jnp.dtype(moment_dtype) if moment_dtype is not None else jnp.float32

    def init(params):
        zeros = lambda: jax.tree.map(lambda x: jnp.zeros_like(x, mdt), params)
        return ScaleByAdamState(count=jnp.zeros([], jnp.int32), mu=zeros(), nu=zeros())

    def update(updates, state, params=None):
        count = state.count + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), updates)
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(mdt),
            state.mu, g32)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * g * g).astype(mdt),
            state.nu, g32)

        t = count.astype(jnp.float32)
        if nesterov_m:
            # Alg. 3 with constant beta1: m̂ = (b1*m_t)/(1-b1^{t+1}) + ((1-b1)*g)/(1-b1^t)
            c_next = 1.0 - b1 ** (t + 1.0)
            c_cur = 1.0 - b1**t
            mu_hat = jax.tree.map(
                lambda m, g: b1 * m / c_next + (1 - b1) * g / c_cur, mu, g32
            )
        elif bias_correction:
            c = 1.0 - b1**t
            mu_hat = jax.tree.map(lambda m: m / c, mu)
        else:
            mu_hat = mu

        if nesterov_v:
            d_next = 1.0 - b2 ** (t + 1.0)
            d_cur = 1.0 - b2**t
            nu_hat = jax.tree.map(
                lambda v, g: b2 * v / d_next + (1 - b2) * g * g / d_cur, nu, g32
            )
        elif nesterov_m:
            # Alg. 3: v̂ = b2*v_t/(1-b2^t)
            d = 1.0 - b2**t
            nu_hat = jax.tree.map(lambda v: b2 * v / d, nu)
        elif bias_correction:
            d = 1.0 - b2**t
            nu_hat = jax.tree.map(lambda v: v / d, nu)
        else:
            nu_hat = nu

        new_updates = jax.tree.map(
            lambda m, v: m.astype(jnp.float32) / (jnp.sqrt(v.astype(jnp.float32)) + eps),
            mu_hat, nu_hat,
        )
        return new_updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def scale_by_adagrad(eps: float = 1e-7) -> GradientTransformation:
    """Adagrad rescaling: u = g/(sqrt(Σ g²)+eps), fp32 accumulator."""

    def init(params):
        return ScaleByAdagradState(
            accum=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        )

    def update(updates, state, params=None):
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), updates)
        accum = jax.tree.map(lambda a, g: a + g * g, state.accum, g32)
        new_updates = jax.tree.map(
            lambda g, a: g / (jnp.sqrt(a) + eps), g32, accum
        )
        return new_updates, ScaleByAdagradState(accum=accum)

    return GradientTransformation(init, update)


def add_decayed_weights(
    weight_decay: float, mask: Optional[PyTree] = None
) -> GradientTransformation:
    """u += wd * params (decoupled weight decay, applied where mask is True).

    Args: ``weight_decay`` = λ of Algorithm 2; ``mask`` is a bool pytree
    aligned with params (None = decay everything).  Invariant: requires
    ``params`` at update time — raises ValueError otherwise.
    """

    def init(params):
        return EmptyState()

    def update(updates, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        if mask is None:
            new = jax.tree.map(
                lambda u, p: u + weight_decay * p.astype(u.dtype), updates, params
            )
        else:
            new = jax.tree.map(
                lambda u, p, m: u + (weight_decay * p.astype(u.dtype) if m else 0.0),
                updates,
                params,
                mask,
            )
        return new, state

    return GradientTransformation(init, update)


def clip_tree_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    """Rescale a pytree so its global L2 norm is at most ``max_norm``.

    The norm reduction always runs in fp32 (dynamic-range safe for bf16
    leaves); leaf dtypes are preserved.  Shared by the ``clip_by_global_norm``
    transform and the fused-LAMB train-step path so both clip identically.
    """
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    gnorm = jnp.sqrt(jnp.sum(jnp.stack(sq)))
    factor = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda x: (x * factor).astype(x.dtype), tree)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    """Stateless transform: scale updates to global L2 norm ≤ ``max_norm``."""

    def init(params):
        return EmptyState()

    def update(updates, state, params=None):
        return clip_tree_by_global_norm(updates, max_norm), state

    return GradientTransformation(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """x_{t+1} = x_t + u_t, preserving param dtypes.

    Invariant: the add happens in fp32 even for low-precision params, so
    small updates are not lost to rounding before the downcast.
    """
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )
