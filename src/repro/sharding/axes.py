"""Logical-axis → mesh-axis resolution.

Every Param carries logical axis names ("embed", "heads", "ff", "experts", ...).
A *rule set* maps logical names to mesh axes; :func:`resolve_spec` turns a
(shape, axes) pair into a PartitionSpec, enforcing XLA constraints:

  * a mesh axis may appear at most once per spec,
  * a dimension must be divisible by the product of its mesh-axis sizes
    (otherwise we progressively drop mesh axes — graceful fallback for e.g.
    MQA's kv_heads=1 or SmolLM's 15 q-heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import module as nn

MeshAxes = Optional[Tuple[str, ...]]


def default_param_rules(multi_pod: bool = False) -> dict:
    """Default logical→mesh rules for *parameters*.

    FSDP: the ``embed`` axis (present in every matmul weight) shards over
    the data axes — ``("data",)``, or ``("pod", "data")`` when
    ``multi_pod`` — so each data-parallel rank holds ``1/N`` of the params
    and optimizer moments.  Tensor/expert parallelism: head, ff and expert
    axes shard over ``model``.  Axes mapped to ``None`` always replicate.
    Callers may copy and override single entries (see
    ``launch/dryrun.py --param-rule``).
    """
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return {
        "vocab": ("model",),
        "embed": fsdp,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "expert_ff": None,
        "head_dim": None,
        "qk_dim": None,
        "v_dim": None,
        "kv_lora": None,
        "q_lora": None,
        "inner": ("model",),   # mamba/xlstm expanded inner dim
        "state": None,
        "conv": None,
        "mtp": None,
        nn.LAYERS_AXIS: None,
    }


def default_act_rules(multi_pod: bool = False) -> dict:
    """Default logical→mesh rules for *activations* (data parallel over
    ``batch``, tensor parallel over head/ff/expert/vocab axes).

    Consumed by :func:`logical_constraint` / ``context.shard_act`` — model
    code annotates activations with logical names and these rules decide
    what (if anything) that means on the current mesh.
    """
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "cache_seq": None,      # overridden to ("data",) for long-context decode
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
    }


def _normalize(rule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def resolve_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Mapping[str, MeshAxes],
    mesh: Mesh,
) -> P:
    """Resolve one tensor's logical axes into a PartitionSpec.

    ``shape`` and ``axes`` run in parallel (one logical name — or ``None``
    — per dimension); ``rules`` maps logical names to mesh-axis tuples and
    ``mesh`` supplies the axis sizes.  Enforces the XLA constraints from
    the module docstring: no mesh axis appears twice, and any dimension
    not divisible by its mesh-axis product gracefully drops trailing axes
    (down to full replication).  Works with ``jax.sharding.AbstractMesh``
    too — only ``mesh.shape`` is consulted — so specs can be computed
    without real devices.
    """
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        mesh_axes = _normalize(rules.get(name)) if name is not None else ()
        # Drop axes not present in the mesh (e.g. "pod" on a single-pod mesh),
        # and axes already used by an earlier dimension.
        mesh_axes = tuple(
            a for a in mesh_axes if a in mesh.shape and a not in used
        )
        # Progressively drop trailing axes until the dim is divisible.
        while mesh_axes:
            total = 1
            for a in mesh_axes:
                total *= mesh.shape[a]
            if dim % total == 0 and dim > 0:
                break
            mesh_axes = mesh_axes[:-1]
        if mesh_axes:
            used.update(mesh_axes)
            out.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
        else:
            out.append(None)
    # Trim trailing Nones for tidy specs.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def specs_for(defs, mesh: Mesh, rules: Optional[Mapping] = None):
    """PartitionSpec tree for a Param definition tree.

    Maps :func:`resolve_spec` over every ``nn.Param`` leaf using its
    declared logical axes; ``rules`` defaults to
    :func:`default_param_rules` (FSDP + TP).  The result mirrors the
    parameter pytree structure and is mesh-device-free (specs only).
    """
    if rules is None:
        rules = default_param_rules(multi_pod="pod" in mesh.shape)

    return nn.tree_map_with_path(
        lambda _, p: resolve_spec(p.shape, p.axes, rules, mesh),
        defs,
        is_leaf=nn.is_param,
    )


def shardings_for(defs, mesh: Mesh, rules: Optional[Mapping] = None):
    """NamedSharding tree for a Param definition tree.

    :func:`specs_for` bound to a concrete ``mesh`` — ready to pass as jit
    ``in_shardings``/``out_shardings`` or to ``jax.device_put``.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_for(defs, mesh, rules))


def spec_sharding(mesh: Mesh, *spec) -> NamedSharding:
    """Shorthand: ``spec_sharding(mesh, "data", None)`` →
    ``NamedSharding(mesh, PartitionSpec("data", None))``."""
    return NamedSharding(mesh, P(*spec))


def constrain(x, mesh: Mesh, *spec):
    """``with_sharding_constraint`` that degrades to a no-op off-mesh.

    Inside jit on a real mesh this pins ``x`` to ``PartitionSpec(*spec)``;
    in single-device unit tests (where the constraint would raise) it
    returns ``x`` unchanged, so library code can annotate unconditionally.
    """
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    except (ValueError, RuntimeError):
        return x


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes present on ``mesh`` (``pod`` before ``data``).

    This is the axis tuple the batch dimension shards over — and hence the
    divisor the global batch size must be a multiple of.
    """
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_size(mesh: Mesh) -> int:
    """Data-parallel way count: product of the :func:`batch_axes` sizes.

    The global batch must be a multiple of this — the single divisor the
    DataPipeline, Trainer and launcher guards all check against.
    """
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def logical_constraint(x, mesh: Mesh, axes: Sequence[Optional[str]], rules=None):
    """Apply a sharding constraint from logical activation axis names.

    Resolves ``axes`` (one name per dimension of ``x``) through the
    activation rules and pins the result — the explicit-mesh sibling of
    ``context.shard_act``, for call sites that hold a mesh rather than an
    ambient :class:`~repro.sharding.context.ShardCtx`.
    """
    if rules is None:
        rules = default_act_rules(multi_pod="pod" in mesh.shape)
    spec = resolve_spec(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
