"""Logical-axis → mesh-axis resolution.

Every Param carries logical axis names ("embed", "heads", "ff", "experts", ...).
A *rule set* maps logical names to mesh axes; :func:`resolve_spec` turns a
(shape, axes) pair into a PartitionSpec, enforcing XLA constraints:

  * a mesh axis may appear at most once per spec,
  * a dimension must be divisible by the product of its mesh-axis sizes
    (otherwise we progressively drop mesh axes — graceful fallback for e.g.
    MQA's kv_heads=1 or SmolLM's 15 q-heads on a 16-way model axis).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import module as nn

MeshAxes = Optional[Tuple[str, ...]]


def default_param_rules(multi_pod: bool = False) -> dict:
    """Default logical→mesh rules: FSDP over (pod,)data, TP/EP over model."""
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return {
        "vocab": ("model",),
        "embed": fsdp,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "expert_ff": None,
        "head_dim": None,
        "qk_dim": None,
        "v_dim": None,
        "kv_lora": None,
        "q_lora": None,
        "inner": ("model",),   # mamba/xlstm expanded inner dim
        "state": None,
        "conv": None,
        "mtp": None,
        nn.LAYERS_AXIS: None,
    }


def default_act_rules(multi_pod: bool = False) -> dict:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "cache_seq": None,      # overridden to ("data",) for long-context decode
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
    }


def _normalize(rule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def resolve_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Mapping[str, MeshAxes],
    mesh: Mesh,
) -> P:
    """Resolve one tensor's logical axes into a PartitionSpec."""
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        mesh_axes = _normalize(rules.get(name)) if name is not None else ()
        # Drop axes not present in the mesh (e.g. "pod" on a single-pod mesh),
        # and axes already used by an earlier dimension.
        mesh_axes = tuple(
            a for a in mesh_axes if a in mesh.shape and a not in used
        )
        # Progressively drop trailing axes until the dim is divisible.
        while mesh_axes:
            total = 1
            for a in mesh_axes:
                total *= mesh.shape[a]
            if dim % total == 0 and dim > 0:
                break
            mesh_axes = mesh_axes[:-1]
        if mesh_axes:
            used.update(mesh_axes)
            out.append(mesh_axes[0] if len(mesh_axes) == 1 else tuple(mesh_axes))
        else:
            out.append(None)
    # Trim trailing Nones for tidy specs.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def specs_for(defs, mesh: Mesh, rules: Optional[Mapping] = None):
    """PartitionSpec tree for a Param definition tree."""
    if rules is None:
        rules = default_param_rules(multi_pod="pod" in mesh.shape)

    return nn.tree_map_with_path(
        lambda _, p: resolve_spec(p.shape, p.axes, rules, mesh),
        defs,
        is_leaf=nn.is_param,
    )


def shardings_for(defs, mesh: Mesh, rules: Optional[Mapping] = None):
    """NamedSharding tree for a Param definition tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_for(defs, mesh, rules))


def spec_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def constrain(x, mesh: Mesh, *spec):
    """with_sharding_constraint helper that is a no-op off-mesh (e.g. unit tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    except (ValueError, RuntimeError):
        return x


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def logical_constraint(x, mesh: Mesh, axes: Sequence[Optional[str]], rules=None):
    """Apply a sharding constraint from logical activation axis names."""
    if rules is None:
        rules = default_act_rules(multi_pod="pod" in mesh.shape)
    spec = resolve_spec(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
