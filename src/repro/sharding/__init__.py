from repro.sharding.axes import (
    batch_axes,
    constrain,
    default_act_rules,
    default_param_rules,
    logical_constraint,
    resolve_spec,
    shardings_for,
    spec_sharding,
    specs_for,
)
from repro.sharding.context import ShardCtx, shard_act, use_sharding

__all__ = [
    "ShardCtx",
    "batch_axes",
    "constrain",
    "default_act_rules",
    "default_param_rules",
    "logical_constraint",
    "resolve_spec",
    "shard_act",
    "shardings_for",
    "spec_sharding",
    "specs_for",
    "use_sharding",
]
