from repro.sharding.axes import (
    batch_axes,
    constrain,
    default_act_rules,
    default_param_rules,
    dp_size,
    logical_constraint,
    resolve_spec,
    shardings_for,
    spec_sharding,
    specs_for,
)
from repro.sharding.context import ShardCtx, shard_act, use_sharding
from repro.sharding.placement import (
    batch_sharding,
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    per_device_state_bytes,
    train_state_shardings,
)

__all__ = [
    "ShardCtx",
    "batch_axes",
    "batch_sharding",
    "batch_shardings",
    "cache_shardings",
    "constrain",
    "default_act_rules",
    "default_param_rules",
    "dp_size",
    "logical_constraint",
    "opt_state_shardings",
    "per_device_state_bytes",
    "resolve_spec",
    "shard_act",
    "shardings_for",
    "spec_sharding",
    "specs_for",
    "train_state_shardings",
    "use_sharding",
]
