"""Concrete placement: logical specs → NamedShardings for whole step states.

:mod:`repro.sharding.axes` resolves *single tensors* (and Param definition
trees) into PartitionSpecs.  This module extends that to everything else a
jit'd step touches — optimizer moments, batches, KV caches, and the full
``TrainState`` triple — so launchers can hand jit explicit
``in_shardings``/``out_shardings`` instead of relying on GSPMD inference
from one annotated input.  The production dry-run and the real ``Trainer``
path share these helpers; what the dry-run compiles is what training runs.

Conventions encoded here:

  * optimizer moment trees (``mu``/``nu``/``momentum``/``accum``) mirror
    their parameter's sharding leaf-for-leaf (FSDP shards the whole
    optimizer, the O(N) win for LAMB's two extra moment buffers);
  * scalar state (schedule counts, the step counter) replicates;
  * batches shard their leading (batch) dimension over the data axes.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.pytree import tree_leaves_with_paths, tree_map_with_path
from repro.sharding.axes import batch_axes, resolve_spec, shardings_for

# Logical axes of every named model input, keyed by batch-dict field.
BATCH_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "mask": ("batch", "seq"),
    "frame_embeds": ("batch", "seq", None),
    "image_embeds": ("batch", None, None),
}


def batch_shardings(batch_abs: Dict[str, Any], mesh: Mesh, rules) -> Dict[str, Any]:
    """Per-field NamedShardings for a model input dict (dry-run path).

    Resolves each field's logical axes (:data:`BATCH_AXES`) through the
    activation rule set, so e.g. ``seq`` can be sharded by a rule override.
    """
    return {
        k: NamedSharding(mesh, resolve_spec(v.shape, BATCH_AXES[k], rules, mesh))
        for k, v in batch_abs.items()
    }


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """One data-parallel NamedSharding for arbitrary batch pytrees.

    Shards the leading (batch) dimension over ``batch_axes(mesh)`` and
    replicates the rest — valid for every leaf of any batch dict because the
    spec is shorter than the array rank (trailing dims replicate).  This is
    the Trainer's placement; :func:`batch_shardings` is the per-field
    variant the dry-run uses when rule overrides shard non-batch axes.
    """
    ba = batch_axes(mesh)
    return NamedSharding(mesh, P(ba if len(ba) > 1 else ba[0]) if ba else P())


def _cache_leaf_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a KV/SSM cache leaf, keyed by its trailing name."""
    name = path.rsplit("/", 1)[-1]
    lead = (None,)  # stacked layers/groups axis
    table = {
        "k": lead + ("batch", "cache_seq", "kv_heads", None),
        "v": lead + ("batch", "cache_seq", "kv_heads", None),
        "c_kv": lead + ("batch", "cache_seq", None),
        "k_rope": lead + ("batch", "cache_seq", None),
        "index": lead,
        "ssm": lead + ("batch", "inner", None),
        "conv": lead + ("batch", None, "inner"),
        "c": lead + ("batch", "heads", None, None),
        "n": lead + ("batch", "heads", None),
        "m": lead + ("batch", "heads"),
        "h": lead + ("batch", "heads", None),
    }
    axes = table.get(name)
    if axes is None or len(axes) != ndim:
        return tuple([None] * ndim)
    return axes


def cache_shardings(cache_abs, mesh: Mesh, rules):
    """NamedSharding tree for a ``make_cache`` pytree (decode/prefill)."""
    return tree_map_with_path(
        lambda p, leaf: NamedSharding(
            mesh, resolve_spec(leaf.shape, _cache_leaf_axes(p, len(leaf.shape)),
                               rules, mesh)
        ),
        cache_abs,
    )


def opt_state_shardings(opt_abs, param_shardings, mesh: Mesh):
    """Match optimizer-state leaves to parameter shardings by path suffix.

    Moment trees (mu/nu/momentum/accum) reuse their parameter's sharding;
    scalars (schedule counts) replicate.  The suffix match is component-
    boundary aware: ``mu/mask_embed`` must not hit the ``embed`` parameter.
    """
    by_path = tree_leaves_with_paths(param_shardings)
    replicated = NamedSharding(mesh, P())

    def match(path: str, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return replicated
        for ppath, psh in by_path:
            if path == ppath or path.endswith("/" + ppath):
                return psh
        return replicated

    return tree_map_with_path(match, opt_abs)


def per_device_state_bytes(tree) -> int:
    """Max over devices of resident bytes for a pytree of (sharded) arrays.

    Sums actual shard buffer sizes per device — the measured FSDP win
    (tests/test_sharded_train.py asserts ≥4× on ``data=8``, and
    ``benchmarks/sharding_bench.py`` records it in BENCH_sharding.json).
    Non-array leaves (and abstract values) contribute nothing.
    """
    per: Dict[int, int] = {}
    for leaf in jax.tree.leaves(tree):
        for s in getattr(leaf, "addressable_shards", []):
            per[s.device.id] = per.get(s.device.id, 0) + s.data.nbytes
    return max(per.values()) if per else 0


def train_state_shardings(
    defs, abstract_state, mesh: Mesh, rules: Optional[Mapping] = None
):
    """Shardings for a full ``TrainState`` (params, opt_state, step).

    ``abstract_state`` is the ShapeDtypeStruct tree from
    ``jax.eval_shape(init_fn, rng)`` — this works for any optimizer state
    layout (fused ``FusedLambState`` or unfused transform chains) because
    moment leaves are matched to parameters by path suffix, not by
    structure.  Returns the same NamedTuple type populated with
    NamedShardings, ready to pass as jit ``in_shardings``/``out_shardings``.
    """
    psh = shardings_for(defs, mesh, rules)
    osh = opt_state_shardings(abstract_state.opt_state, psh, mesh)
    replicated = NamedSharding(mesh, P())
    rest = {
        f: replicated for f in abstract_state._fields
        if f not in ("params", "opt_state")
    }
    return type(abstract_state)(params=psh, opt_state=osh, **rest)
