"""Ambient sharding context.

Model code annotates activations with *logical* axis names via
:func:`shard_act`.  Whether (and how) that becomes a
``with_sharding_constraint`` is decided by the ambient :class:`ShardCtx`
installed by the launcher / dry-run.  Unit tests and single-device smoke runs
simply never install a context, and every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.axes import default_act_rules, resolve_spec

_state = threading.local()


class ShardCtx:
    """A mesh plus the activation rule set annotations resolve against.

    Install with :func:`use_sharding`; model code then sees it through
    :func:`shard_act`.  ``act_rules`` defaults to
    :func:`~repro.sharding.axes.default_act_rules` for the mesh's pod
    structure; :meth:`with_rules` derives a context with single-rule
    overrides (e.g. ``cache_seq=("data",)`` for long-context decode).
    """

    def __init__(self, mesh: Mesh, act_rules: Optional[Mapping] = None):
        self.mesh = mesh
        self.act_rules = dict(
            act_rules
            if act_rules is not None
            else default_act_rules(multi_pod="pod" in mesh.shape)
        )

    def with_rules(self, **overrides) -> "ShardCtx":
        """New context with the given activation rules replaced."""
        rules = dict(self.act_rules)
        rules.update(overrides)
        return ShardCtx(self.mesh, rules)


def current() -> Optional[ShardCtx]:
    """The ambient :class:`ShardCtx` of this thread, or ``None``."""
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardCtx]):
    """Install ``ctx`` as the ambient sharding context for the block.

    Must wrap *tracing* (the first call of a jit'd function), not
    execution: ``shard_act`` reads the context when the constraint is
    staged out.  Passing ``None`` explicitly disables annotations inside
    the block (restoring the previous context on exit either way).
    """
    prev = current()
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = prev


def shard_act(x, axes: Sequence[Optional[str]]):
    """Annotate activation ``x`` with logical axis names.

    With no ambient context this is the identity (single-device tests);
    with one, the names resolve through the context's activation rules to
    a ``with_sharding_constraint`` on the context's mesh.  ``axes`` must
    name every dimension of ``x`` (use ``None`` for replicated dims).
    """
    ctx = current()
    if ctx is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank mismatch: {x.shape} vs logical axes {axes}")
    spec = resolve_spec(x.shape, axes, ctx.act_rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
