"""Ambient sharding context.

Model code annotates activations with *logical* axis names via
:func:`shard_act`.  Whether (and how) that becomes a
``with_sharding_constraint`` is decided by the ambient :class:`ShardCtx`
installed by the launcher / dry-run.  Unit tests and single-device smoke runs
simply never install a context, and every annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.axes import default_act_rules, resolve_spec

_state = threading.local()


class ShardCtx:
    def __init__(self, mesh: Mesh, act_rules: Optional[Mapping] = None):
        self.mesh = mesh
        self.act_rules = dict(
            act_rules
            if act_rules is not None
            else default_act_rules(multi_pod="pod" in mesh.shape)
        )

    def with_rules(self, **overrides) -> "ShardCtx":
        rules = dict(self.act_rules)
        rules.update(overrides)
        return ShardCtx(self.mesh, rules)


def current() -> Optional[ShardCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(ctx: Optional[ShardCtx]):
    prev = current()
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = prev


def shard_act(x, axes: Sequence[Optional[str]]):
    """Annotate an activation with logical axes (no-op without a ShardCtx)."""
    ctx = current()
    if ctx is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank mismatch: {x.shape} vs logical axes {axes}")
    spec = resolve_spec(x.shape, axes, ctx.act_rules, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
