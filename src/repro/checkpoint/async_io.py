"""Async double-buffered checkpointing: the step loop never blocks on disk.

At BERT-in-76-minutes scale a synchronous save is a direct tax on the
wall-clock headline: gathering every leaf to host *and* serializing it to
disk inside the step loop stalls the device for the full write.  An
:class:`AsyncCheckpointer` splits the save into the two phases that have
very different costs:

1. **snapshot** (main thread, bounded by device→host bandwidth): every leaf
   starts a non-blocking ``copy_to_host_async``, then the transfers are
   gathered into host numpy buffers.  This must finish before ``save``
   returns — the Trainer's jit'd step *donates* the state, so the device
   buffers are dead the moment the next step is dispatched.
2. **write** (background thread, bounded by disk): the host snapshot is
   serialized through the same atomic tmp-dir/rename + LATEST protocol as
   the sync path (:func:`~repro.checkpoint.io.write_checkpoint_dir`), fully
   overlapped with subsequent training steps.

"Double-buffered": while write *N* is still in flight, ``save`` for step
*N+1* takes its host snapshot concurrently (two host buffers alive at
once); only then does it wait for write *N*, so at most one write is ever
in flight and back-to-back saves degrade gracefully to disk speed instead
of queueing unboundedly.

Telemetry: each completed save emits one ``checkpoint`` event
(``mode="async"``) carrying ``snapshot_s`` (time the step loop paid for the
device→host gather), ``blocked_s`` (time ``save`` waited on the previous
in-flight write — ~0 unless saves outpace the disk) and ``write_s`` (the
overlapped background wall time).  ``RunReport`` folds these into the
``checkpoints.async`` section that the telemetry gate regression-checks.

Crash semantics are inherited from :mod:`repro.checkpoint.io`: a SIGKILL at
any point leaves either the previous LATEST intact or a fully renamed new
checkpoint, never a torn pointer; partial ``.tmp_ckpt_*`` debris is
garbage-collected by the next save.  ``latest_persisted_step`` reports only
checkpoints whose rename completed — the resume contract.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.checkpoint.io import (
    checkpoint_step,
    latest_checkpoint,
    write_checkpoint_dir,
)
from repro.common.pytree import tree_leaves_with_paths
from repro.telemetry import EventLog


def _host_snapshot(tree: Any) -> List[Tuple[str, np.ndarray]]:
    """Gather every leaf to host, overlapping the device→host transfers.

    All leaves start an async copy first, so the subsequent ``np.asarray``
    calls wait on transfers that ran concurrently — one D2H pass over the
    whole state, not a serial per-leaf sync.  Leaves that cannot copy async
    (host numpy, non-addressable layouts) fall through to the plain gather.
    """
    leaves = tree_leaves_with_paths(tree)
    for _, leaf in leaves:
        start = getattr(leaf, "copy_to_host_async", None)
        if start is not None:
            try:
                start()
            except Exception:  # backend-dependent; the gather below still works
                pass
    return [(path, np.asarray(leaf)) for path, leaf in leaves]


class AsyncCheckpointer:
    """Double-buffered async saves of a full train-state pytree.

    ``save`` blocks only for the host snapshot (device→host), hands the
    write to a single background worker, and returns; ``wait`` drains the
    in-flight write (re-raising its exception, if any).  At most one write
    is in flight at a time.
    """

    def __init__(self, directory: str, *, telemetry: Optional[EventLog] = None):
        self.directory = directory
        self.telemetry = telemetry if telemetry is not None else EventLog()
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt-write"
        )
        self._future: Optional[Future] = None
        # resume-aware: a pre-existing complete checkpoint counts as persisted
        existing = latest_checkpoint(directory)
        self._latest_persisted: Optional[int] = (
            checkpoint_step(existing) if existing else None
        )

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        """Snapshot ``tree`` and schedule its write; never blocks on disk.

        Ordering matters: snapshot *before* waiting on the previous write,
        so a slow disk overlaps with the new snapshot (the double buffer)
        — and the snapshot itself must complete here because the caller's
        jit'd step donates these device buffers on the next dispatch.
        """
        t0 = time.perf_counter()
        host = _host_snapshot(tree)
        snapshot_s = time.perf_counter() - t0

        t1 = time.perf_counter()
        self.wait()  # at most one write in flight; ~0 when disk keeps up
        blocked_s = time.perf_counter() - t1

        self._future = self._executor.submit(
            self._write, int(step), host, snapshot_s, blocked_s
        )

    def _write(self, step: int, host, snapshot_s: float,
               blocked_s: float) -> str:
        t0 = time.perf_counter()
        path = write_checkpoint_dir(self.directory, step, host)
        write_s = time.perf_counter() - t0
        self._latest_persisted = step
        self.telemetry.emit(
            "checkpoint", step=step, path=path, mode="async",
            snapshot_s=snapshot_s, blocked_s=blocked_s, write_s=write_s,
        )
        return path

    # ------------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> Optional[str]:
        """Block until the in-flight write (if any) is durable.

        Returns the persisted checkpoint path, or None if nothing was in
        flight.  A failed background write re-raises here — on the step
        loop's thread — instead of being swallowed.

        ``timeout`` (seconds) bounds the wait — the preemption grace
        window.  On timeout the write is left in flight (it may still
        complete before process exit; the atomic rename protocol keeps the
        previous checkpoint intact either way) and None is returned.
        """
        future = self._future
        if future is None:
            return None
        try:
            result = future.result(timeout)
        except (_FuturesTimeout, TimeoutError):
            return None
        except BaseException:
            self._future = None
            raise
        self._future = None
        return result

    def latest_persisted_step(self) -> Optional[int]:
        """Step of the newest checkpoint whose atomic rename completed.

        This — not the last ``save`` call — is what a resume will see after
        a crash right now.
        """
        return self._latest_persisted

    def close(self) -> None:
        self.wait()
        self._executor.shutdown(wait=True)

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
