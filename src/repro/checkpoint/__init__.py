from repro.checkpoint.async_io import AsyncCheckpointer
from repro.checkpoint.io import (
    checkpoint_step,
    discard_checkpoints_after,
    gc_tmp_dirs,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
    write_checkpoint_dir,
)

__all__ = [
    "AsyncCheckpointer",
    "checkpoint_step",
    "discard_checkpoints_after",
    "gc_tmp_dirs",
    "latest_checkpoint",
    "restore_checkpoint",
    "save_checkpoint",
    "write_checkpoint_dir",
]
