"""Checkpointing: pytree ⇄ directory of .npy leaves + JSON manifest.

No orbax in this environment; this is a small but complete implementation:
atomic writes (tmp dir + rename), step-numbered checkpoints, latest-pointer,
restore onto abstract targets (dtype/shape checked), optimizer state
round-trips because states are plain pytrees of arrays/ints.

Crash consistency is the contract every writer here upholds:

* a checkpoint directory becomes visible only via ``os.rename`` of a fully
  written tmp dir, so ``step_*`` either has a complete manifest or does not
  exist;
* the ``LATEST`` pointer is itself written tmp-file-then-rename, so a crash
  between checkpoint rename and pointer update can't leave a torn pointer;
* ``latest_checkpoint`` trusts the pointer only if it names a *complete*
  checkpoint and otherwise falls back to the newest complete ``step_*`` dir
  (a crash after checkpoint rename but before pointer rename loses nothing);
* stray ``.tmp_ckpt_*`` / ``.tmp_latest_*`` debris from a killed writer is
  garbage-collected at the start of the next save.

Sharded states: ``save_checkpoint`` accepts mesh-sharded arrays directly
(``np.asarray`` gathers the global value on a single process), and
``restore_checkpoint(..., shardings=)`` places each leaf with
``jax.device_put`` onto its NamedSharding — so a checkpoint written from a
``data=8`` FSDP run restores onto a ``data=4,model=2`` mesh (or a single
device) without a resharding step: the mesh layout lives in the restore
target, never in the file format.

Extension dtypes (bf16, fp8 — numpy kind ``'V'`` via ml_dtypes) are stored
as same-width unsigned-int views with the real dtype in the manifest; a
plain ``np.save`` of such arrays silently degrades to raw void records that
cannot be viewed back without the manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

from repro.common.pytree import tree_leaves_with_paths

# Test-only fault-injection hook: when set, called as ``hook(i, tmp_dir)``
# after the i-th leaf file of a checkpoint is written (before the atomic
# rename).  The preemption harness uses it to SIGKILL a run mid-save; unit
# tests raise from it to simulate write failures.  Never set in production.
after_leaf_write: Optional[Callable[[int, str], None]] = None

_TMP_PREFIXES = (".tmp_ckpt_", ".tmp_latest_")


def _sanitize(path: str) -> str:
    return path.replace("/", "__")


def _uint_view(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """Savable (array, manifest-dtype) pair; extension dtypes -> uint views."""
    dtype = str(arr.dtype)
    if arr.dtype.kind == "V":  # ml_dtypes extension type (bf16, fp8, ...)
        arr = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
    return arr, dtype


def _from_uint_view(arr: np.ndarray, dtype: str) -> np.ndarray:
    if str(arr.dtype) != dtype:
        arr = arr.view(np.dtype(dtype))
    return arr


def gc_tmp_dirs(directory: str) -> List[str]:
    """Remove stray ``.tmp_ckpt_*`` dirs / ``.tmp_latest_*`` files left by a
    crashed writer.  Called at the start of every save; safe because at most
    one save is ever in flight per directory (the AsyncCheckpointer
    serializes its writes, and concurrent writers to one directory are not a
    supported topology)."""
    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in os.listdir(directory):
        if not name.startswith(_TMP_PREFIXES):
            continue
        path = os.path.join(directory, name)
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
        else:
            try:
                os.remove(path)
            except OSError:
                continue
        removed.append(name)
    return removed


def _write_latest(directory: str, name: str) -> None:
    """Atomically point LATEST at ``name`` (tmp file + rename, never torn)."""
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp_latest_")
    with os.fdopen(fd, "w") as f:
        f.write(name)
    os.rename(tmp, os.path.join(directory, "LATEST"))


def write_checkpoint_dir(
    directory: str, step: int, leaves: List[Tuple[str, np.ndarray]]
) -> str:
    """Atomically publish host-side ``(path, array)`` leaves as step_<N>.

    The shared write path under ``save_checkpoint`` and the background
    thread of :class:`~repro.checkpoint.async_io.AsyncCheckpointer`; the
    caller owns getting leaves to host (``np.asarray`` / async D2H).
    """
    os.makedirs(directory, exist_ok=True)
    gc_tmp_dirs(directory)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(leaves):
            arr = np.asarray(arr)
            fname = _sanitize(path) + ".npy"
            savable, dtype = _uint_view(arr)
            np.save(os.path.join(tmp, fname), savable)
            if after_leaf_write is not None:
                after_leaf_write(i, tmp)
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": dtype,
                 "shape": list(arr.shape)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(directory, os.path.basename(final))
    return final


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write `tree` under directory/step_<N>/ atomically. Returns the path."""
    leaves = [(p, np.asarray(leaf)) for p, leaf in tree_leaves_with_paths(tree)]
    return write_checkpoint_dir(directory, step, leaves)


def _is_complete(path: str) -> bool:
    return os.path.isfile(os.path.join(path, "manifest.json"))


def _step_of(name: str) -> Optional[int]:
    try:
        return int(name[len("step_"):])
    except ValueError:
        return None


def latest_checkpoint(directory: str,
                      max_step: Optional[int] = None) -> Optional[str]:
    """Path of the newest *complete* checkpoint, or None.

    The LATEST pointer is authoritative when it names a complete checkpoint;
    otherwise (missing, stale after a crashed writer, or pointing at debris)
    fall back to the newest ``step_*`` dir that has a manifest — renames are
    atomic, so "has a manifest" is exactly "was fully written".

    ``max_step`` bounds the search to checkpoints with ``step <= max_step``
    (the supervisor's rollback target: the newest checkpoint a healthy loss
    observation has *validated* — a save that raced ahead of a poisoned
    update must not come back).
    """
    if not os.path.isdir(directory):
        return None
    if max_step is None:
        pointer = os.path.join(directory, "LATEST")
        if os.path.exists(pointer):
            with open(pointer) as f:
                name = f.read().strip()
            path = os.path.join(directory, name)
            if os.path.isdir(path) and _is_complete(path):
                return path
    for name in sorted(os.listdir(directory), reverse=True):
        if not name.startswith("step_"):
            continue
        step = _step_of(name)
        if step is None or (max_step is not None and step > max_step):
            continue
        path = os.path.join(directory, name)
        if os.path.isdir(path) and _is_complete(path):
            return path
    return None


def discard_checkpoints_after(directory: str, step: int) -> List[str]:
    """Remove every checkpoint with ``step > step`` and re-point LATEST.

    The rollback invalidation step: checkpoints newer than the restored one
    may hold poisoned state, and both future in-run saves (same step number
    after the counter rewinds) and a later ``--resume`` must never see
    them.  Returns the removed directory names."""
    removed: List[str] = []
    if not os.path.isdir(directory):
        return removed
    keep_newest: Optional[int] = None
    for name in os.listdir(directory):
        if not name.startswith("step_"):
            continue
        s = _step_of(name)
        if s is None:
            continue
        if s > step:
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
            removed.append(name)
        elif _is_complete(os.path.join(directory, name)):
            keep_newest = s if keep_newest is None else max(keep_newest, s)
    if keep_newest is not None:
        _write_latest(directory, f"step_{keep_newest:08d}")
    else:
        try:
            os.remove(os.path.join(directory, "LATEST"))
        except OSError:
            pass
    return removed


def restore_checkpoint(
    path: str, target: Any, shardings: Any = None, *, cast: bool = False
) -> Any:
    """Restore into the structure of `target` (arrays or ShapeDtypeStructs).

    ``shardings``, when given, is a pytree of ``jax.sharding.Sharding``
    matching ``target`` (e.g. from ``sharding.shardings_for`` /
    ``train_state_shardings``): each leaf is ``device_put`` onto its
    sharding as it loads, so a restore onto an N-device mesh materializes
    only ``1/N`` of each FSDP-sharded leaf per device.  Without it, leaves
    come back as host numpy arrays (the original behavior).

    Shape mismatches always raise; dtype mismatches raise unless
    ``cast=True`` explicitly opts into converting each stored leaf to its
    target dtype (a silent cast would otherwise mask e.g. restoring fp32
    masters from a truncated bf16 checkpoint).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    from repro.common.pytree import path_str

    sh_by_path = {}
    if shardings is not None:
        sflat, _ = jax.tree_util.tree_flatten_with_path(shardings)
        sh_by_path = {path_str(kp): s for kp, s in sflat}

    leaves = []
    for kp, tgt in flat:
        p = path_str(kp)
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        entry = by_path[p]
        arr = _from_uint_view(
            np.load(os.path.join(path, entry["file"])), entry["dtype"]
        )
        tgt_shape = tuple(tgt.shape)
        if tuple(arr.shape) != tgt_shape:
            raise ValueError(f"{p}: shape {arr.shape} != target {tgt_shape}")
        if arr.dtype != np.dtype(tgt.dtype):
            if not cast:
                raise ValueError(
                    f"{p}: dtype {arr.dtype} != target {np.dtype(tgt.dtype)} "
                    f"(pass cast=True to convert)"
                )
            arr = arr.astype(tgt.dtype)
        if p in sh_by_path:
            arr = jax.device_put(arr, sh_by_path[p])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return int(json.load(f)["step"])
