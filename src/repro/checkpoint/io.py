"""Checkpointing: pytree ⇄ directory of .npy leaves + JSON manifest.

No orbax in this environment; this is a small but complete implementation:
atomic writes (tmp dir + rename), step-numbered checkpoints, latest-pointer,
restore onto abstract targets (dtype/shape checked), optimizer state
round-trips because states are plain pytrees of arrays/ints.

Sharded states: ``save_checkpoint`` accepts mesh-sharded arrays directly
(``np.asarray`` gathers the global value on a single process), and
``restore_checkpoint(..., shardings=)`` places each leaf with
``jax.device_put`` onto its NamedSharding — so a checkpoint written from a
``data=8`` FSDP run restores onto a ``data=4,model=2`` mesh (or a single
device) without a resharding step: the mesh layout lives in the restore
target, never in the file format.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.common.pytree import tree_leaves_with_paths


def _sanitize(path: str) -> str:
    return path.replace("/", "__")


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Write `tree` under directory/step_<N>/ atomically. Returns the path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        manifest = {"step": step, "leaves": []}
        for path, leaf in tree_leaves_with_paths(tree):
            arr = np.asarray(leaf)
            fname = _sanitize(path) + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": str(arr.dtype),
                 "shape": list(arr.shape)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(os.path.basename(final))
    return final


def latest_checkpoint(directory: str) -> Optional[str]:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    return path if os.path.isdir(path) else None


def restore_checkpoint(path: str, target: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `target` (arrays or ShapeDtypeStructs).

    ``shardings``, when given, is a pytree of ``jax.sharding.Sharding``
    matching ``target`` (e.g. from ``sharding.shardings_for`` /
    ``train_state_shardings``): each leaf is ``device_put`` onto its
    sharding as it loads, so a restore onto an N-device mesh materializes
    only ``1/N`` of each FSDP-sharded leaf per device.  Without it, leaves
    come back as host numpy arrays (the original behavior).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    from repro.common.pytree import path_str

    sh_by_path = {}
    if shardings is not None:
        sflat, _ = jax.tree_util.tree_flatten_with_path(shardings)
        sh_by_path = {path_str(kp): s for kp, s in sflat}

    leaves = []
    for kp, tgt in flat:
        p = path_str(kp)
        if p not in by_path:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        entry = by_path[p]
        arr = np.load(os.path.join(path, entry["file"]))
        tgt_shape = tuple(tgt.shape)
        if tuple(arr.shape) != tgt_shape:
            raise ValueError(f"{p}: shape {arr.shape} != target {tgt_shape}")
        leaf = arr.astype(tgt.dtype)
        if p in sh_by_path:
            leaf = jax.device_put(leaf, sh_by_path[p])
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return int(json.load(f)["step"])
