from repro.nn.module import (
    LAYERS_AXIS,
    Param,
    abstract_params,
    cast_tree,
    init_params,
    is_param,
    layer_axis_tree,
    logical_axes_tree,
    param_count,
    stack,
    trust_ratio_mask,
    weight_decay_mask,
)

__all__ = [
    "LAYERS_AXIS",
    "Param",
    "abstract_params",
    "cast_tree",
    "init_params",
    "is_param",
    "layer_axis_tree",
    "logical_axes_tree",
    "param_count",
    "stack",
    "trust_ratio_mask",
    "weight_decay_mask",
]
