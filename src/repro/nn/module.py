"""Functional parameter-definition system.

Models declare their parameters as nested dicts of :class:`Param`, each carrying
its shape, dtype, initializer and *logical sharding axes*.  From one definition
tree we derive:

  * concrete parameters        (``init_params``)
  * ShapeDtypeStruct stand-ins (``abstract_params``)  — used by the dry-run
  * PartitionSpec trees        (``repro.sharding.specs_for``)
  * scan metadata              (``layer_axis_tree``)   — used by the scan-aware
    layerwise optimizer (per-layer trust ratios on stacked leaves)

No flax dependency; everything is plain pytrees + pure functions.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.pytree import path_str

# Logical axis name used for stacked (scanned) layer parameters.
LAYERS_AXIS = "layers"


@dataclasses.dataclass(frozen=True)
class Param:
    """Declaration of a single weight tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | uniform_scalar
    dtype: Any = jnp.float32
    scale: float = 1.0
    # metadata consumed by the optimizer layer:
    no_weight_decay: bool = False  # e.g. norm scales / biases
    no_trust_ratio: bool = False   # excluded from layerwise adaptation

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"Param shape {self.shape} and axes {self.axes} rank mismatch"
            )


def is_param(x) -> bool:
    return isinstance(x, Param)


def tree_map_with_path(fn, tree, *rest, is_leaf=None):
    return jax.tree_util.tree_map_with_path(
        lambda kp, x, *r: fn(path_str(kp), x, *r), tree, *rest, is_leaf=is_leaf
    )


def _param_tree_map(fn: Callable[[str, Param], Any], defs):
    return tree_map_with_path(fn, defs, is_leaf=is_param)


def stack(defs, n_layers: int):
    """Prepend a stacked-layers axis to every Param in `defs` (for lax.scan)."""

    def add_axis(_, p: Param) -> Param:
        return dataclasses.replace(
            p, shape=(n_layers,) + tuple(p.shape), axes=(LAYERS_AXIS,) + tuple(p.axes)
        )

    return _param_tree_map(add_axis, defs)


def _fold_path(rng: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(rng, h)


def _initialize(p: Param, key: jax.Array) -> jax.Array:
    shape = tuple(p.shape)
    if p.init == "zeros":
        return jnp.zeros(shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(shape, p.dtype)
    if p.init == "embed":
        return (p.scale * jax.random.normal(key, shape)).astype(p.dtype)
    if p.init == "normal":
        return (p.scale * jax.random.normal(key, shape)).astype(p.dtype)
    if p.init == "uniform_scalar":
        # e.g. SSM dt / A params: uniform in (0, scale]
        u = jax.random.uniform(key, shape, minval=1e-3, maxval=1.0)
        return (p.scale * u).astype(p.dtype)
    if p.init == "fan_in":
        # fan-in from the second-to-last dim (matmul convention), skipping the
        # stacked-layers axis which is axis 0 when present.
        dims = [d for d, a in zip(shape, p.axes) if a != LAYERS_AXIS]
        fan_in = dims[-2] if len(dims) >= 2 else dims[-1]
        std = p.scale / max(fan_in, 1) ** 0.5
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(
            p.dtype
        )
    raise ValueError(f"unknown init {p.init!r}")


def init_params(defs, rng: jax.Array):
    """Materialize a definition tree into concrete arrays (deterministic per path)."""
    return _param_tree_map(lambda path, p: _initialize(p, _fold_path(rng, path)), defs)


def abstract_params(defs):
    """ShapeDtypeStruct tree (no allocation) — dry-run input."""
    return _param_tree_map(
        lambda _, p: jax.ShapeDtypeStruct(tuple(p.shape), jnp.dtype(p.dtype)), defs
    )


def logical_axes_tree(defs):
    return _param_tree_map(lambda _, p: tuple(p.axes), defs)


def layer_axis_tree(defs):
    """Tree of ints: index of the stacked-layers axis per leaf, -1 if unstacked.

    (-1 rather than None: None is an empty pytree node and would break
    tree_map alignment.)  The layerwise optimizer uses this to compute
    per-layer (per-slice) norms on scanned parameter stacks.
    """

    def f(_, p: Param):
        return p.axes.index(LAYERS_AXIS) if LAYERS_AXIS in p.axes else -1

    return _param_tree_map(f, defs)


def weight_decay_mask(defs):
    """True where weight decay applies (paper/reference impl: skip norms+biases)."""
    return _param_tree_map(lambda _, p: not p.no_weight_decay, defs)


def trust_ratio_mask(defs):
    """True where the layerwise trust ratio applies."""
    return _param_tree_map(lambda _, p: not p.no_trust_ratio, defs)


def param_count(defs) -> int:
    total = 0
    for leaf in jax.tree.leaves(defs, is_leaf=is_param):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
