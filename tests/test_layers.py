"""Layer-level consistency: MoE routing algebra, mamba parallel-vs-recurrent,
mLSTM parallel-vs-recurrent, sliding-window masks, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.layers import mamba as mamba_mod
from repro.models.layers import moe as moe_mod
from repro.models.layers import xlstm as xlstm_mod
from repro.models.layers.embeddings import apply_rope

CFG = ModelConfig(
    name="layer-test", family="moe", n_layers=2, d_model=32, n_heads=4,
    n_kv_heads=4, d_ff=64, vocab_size=64, n_experts=4, n_experts_per_tok=2,
    moe_d_ff=16, capacity_factor=8.0, activation_dtype="float32",
)


def test_moe_matches_dense_mixture_when_capacity_ample(key):
    """With no drops, MoE == explicit per-token gated mixture of expert MLPs."""
    p = nn.init_params(moe_mod.moe_defs(CFG), key)
    x = jax.random.normal(jax.random.key(1), (2, 6, 32), jnp.float32)
    out, aux = moe_mod.moe(p, x, CFG)
    assert float(aux["moe_drop_fraction"]) == 0.0

    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)

    def expert(e, t):
        h = jax.nn.silu(xf[t] @ p["wg"][e]) * (xf[t] @ p["wi"][e])
        return h @ p["wo"][e]

    want = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        for j in range(2):
            want[t] += float(gates[t, j]) * np.asarray(expert(int(idx[t, j]), t))
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, 32)), want, rtol=2e-4, atol=2e-4
    )


def test_moe_gates_renormalized(key):
    p = nn.init_params(moe_mod.moe_defs(CFG), key)
    logits = jax.random.normal(key, (10, 4))
    gates, idx, aux = moe_mod.route(logits, CFG)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux["moe_lb_loss"]) > 0.9  # ~1 balanced, grows with skew


def test_moe_capacity_drops_accounted(key):
    cfg = CFG.replace(capacity_factor=0.25)
    p = nn.init_params(moe_mod.moe_defs(cfg), key)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    out, aux = moe_mod.moe(p, x, cfg)
    assert 0.0 < float(aux["moe_drop_fraction"]) < 1.0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_mamba_parallel_equals_recurrent(key):
    cfg = CFG.replace(mamba_expand=2, mamba_d_state=4, mamba_d_conv=3)
    p = nn.init_params(mamba_mod.mamba_defs(cfg), key)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)

    # parallel over the whole sequence (with state tracking)
    st0 = mamba_mod.init_mamba_state(2, cfg, jnp.float32)
    y_par, st_par = mamba_mod.mamba(p, x, cfg, state=st0)

    # recurrent token-by-token
    st = mamba_mod.init_mamba_state(2, cfg, jnp.float32)
    ys = []
    for t in range(8):
        y_t, st = mamba_mod.mamba(p, x[:, t:t + 1], cfg, state=st, decode=True)
        ys.append(y_t)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_par["ssm"]), np.asarray(st["ssm"]),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_full(key):
    cfg = CFG.replace(mamba_expand=2, mamba_d_state=4)
    p = nn.init_params(mamba_mod.mamba_defs(cfg), key)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y_full, _ = mamba_mod.mamba(p, x, cfg)
    y_chunk, _ = mamba_mod.mamba(p, x, cfg, chunk=4)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chunk),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_parallel_equals_recurrent(key):
    cfg = CFG.replace(n_heads=2, n_kv_heads=2, xlstm_proj_factor=2.0)
    p = nn.init_params(xlstm_mod.mlstm_defs(cfg), key)
    x = jax.random.normal(jax.random.key(1), (2, 6, 32), jnp.float32)

    y_par, _ = xlstm_mod.mlstm_block(p, x, cfg)

    st = xlstm_mod.init_mlstm_state(2, cfg)
    ys = []
    for t in range(6):
        y_t, st = xlstm_mod.mlstm_block(p, x[:, t:t + 1], cfg, state=st, decode=True)
        ys.append(y_t)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=3e-4, atol=3e-4)


def test_slstm_decode_continues_scan(key):
    cfg = CFG.replace(n_heads=2, n_kv_heads=2)
    p = nn.init_params(xlstm_mod.slstm_defs(cfg), key)
    x = jax.random.normal(jax.random.key(1), (1, 7, 32), jnp.float32)
    st0 = xlstm_mod.init_slstm_state(1, cfg)
    y_full, st_full = xlstm_mod.slstm_block(p, x, cfg, state=st0)

    y_pre, st = xlstm_mod.slstm_block(p, x[:, :6], cfg,
                                      state=xlstm_mod.init_slstm_state(1, cfg))
    y_last, st = xlstm_mod.slstm_block(p, x[:, 6:7], cfg, state=st, decode=True)
    np.testing.assert_allclose(np.asarray(y_full[:, -1]), np.asarray(y_last[:, 0]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_old_tokens(key):
    """With window w, logits at position t must not depend on tokens < t-w+1."""
    from repro.models.layers.attention import attention, attention_defs

    cfg = CFG.replace(sliding_window=4, n_kv_heads=2, use_rope=False)
    p = nn.init_params(attention_defs(cfg), key)
    x1 = jax.random.normal(jax.random.key(1), (1, 12, 32), jnp.float32)
    x2 = x1.at[:, 0:4].set(jax.random.normal(jax.random.key(2), (1, 4, 32)))
    pos = jnp.arange(12)[None]
    y1, _ = attention(p, x1, pos, cfg)
    y2, _ = attention(p, x2, pos, cfg)
    # positions >= 8 attend only within [t-3, t] → unaffected by tokens 0..3
    np.testing.assert_allclose(np.asarray(y1[:, 8:]), np.asarray(y2[:, 8:]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, :4] - y2[:, :4]))) > 1e-3


def test_rope_relative_property(key):
    """<rope(q,m), rope(k,n)> depends only on (m-n)."""
    d = 64
    q = jax.random.normal(key, (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, d))

    def dot_at(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m), 10000.0)
        kn = apply_rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), rel=1e-4)
