"""Sharded training end-to-end on 8 virtual CPU devices.

The multi-device work runs in ONE subprocess (tests/sharded_harness.py,
which sets ``--xla_force_host_platform_device_count=8`` before importing
jax — the flag is dead after backend init, so it cannot be set from this
process).  The module-scoped fixture runs every scenario once; the tests
below assert on slices of its JSON report, plus a few in-process unit
checks that need no devices.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# LAMB amplifies reduction-order noise through the trust ratio, so sharded
# vs single-device is allclose, not bitwise (measured ~3e-3 after 3 steps
# on the TP mesh; a placement bug shows up one-plus orders larger).
PARAM_TOL = 2e-2
LOSS_TOL = 1e-2


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # the harness sets its own device count
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "sharded_harness.py")],
        capture_output=True, text=True, timeout=1800, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.splitlines()[-1])


def test_harness_sees_8_devices(report):
    assert report["devices"] == 8


@pytest.mark.parametrize("variant", ["unfused", "fused", "accum2_bf16"])
@pytest.mark.parametrize("mesh", ["data=8,model=1", "data=4,model=2"])
def test_sharded_step_matches_single_device(report, variant, mesh):
    entry = report["equiv"][variant][mesh]
    assert entry["param_maxdiff"] < PARAM_TOL, entry
    assert entry["loss_diff"] < LOSS_TOL, entry


@pytest.mark.parametrize("variant", ["fp32", "accum2_bf16"])
@pytest.mark.parametrize("mesh", ["data=8,model=1", "data=4,model=2"])
def test_lans_sharded_matches_single_device(report, variant, mesh):
    """LANS normalizes each gradient block by its norm BEFORE the moments, so
    a per-slice reduction that silently went device-local under GSPMD would
    skew every step; sharded must stay allclose to single-device."""
    entry = report["lans"][variant][mesh]
    assert entry["param_maxdiff"] < PARAM_TOL, entry
    assert entry["loss_diff"] < LOSS_TOL, entry


@pytest.mark.parametrize("head", ["fused_ce", "dense_head"])
@pytest.mark.parametrize("mesh", ["data=8,model=1", "data=4,model=2"])
def test_mlm_flash_fused_sharded_matches(report, head, mesh):
    """The paper path: bert MLM through flash attention + fused LAMB, with
    both the fused-CE head (gather + chunked-vocab CE — vocab-chunk
    reductions must stay global under GSPMD) and the dense logits head."""
    entry = report["mlm_flash"][head][mesh]
    assert entry["param_maxdiff"] < PARAM_TOL, entry
    assert entry["loss_diff"] < LOSS_TOL, entry


def test_mixed_batch_stages_run_sharded(report):
    assert report["stages"]["final_step"] == 4
    assert report["stages"]["finite"]


def test_checkpoint_roundtrips_across_mesh_shapes(report):
    ck = report["checkpoint"]
    assert ck["param_maxdiff"] == 0.0, ck   # exact: save/restore, no math
    assert ck["moment_maxdiff"] == 0.0, ck
    assert ck["shardings_match"]
    assert ck["post_restore_step"] == 3
    assert ck["post_restore_loss_finite"]


# ---------------------------------------------------------------------------
# preemption / fault injection (crash_resume scenario)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["mid_training", "mid_save"])
def test_crash_leaves_directory_consistent(report, case):
    """After a SIGKILL — including one landing mid-save — LATEST must name a
    fully written checkpoint (atomic rename means manifest present ⟺
    complete), with the step a multiple of the checkpoint cadence."""
    entry = report["crash_resume"][case]
    assert entry["latest_step"] is not None, entry
    assert entry["latest_step"] % 2 == 0, entry
    assert entry["pointer_names_complete"], entry


def test_mid_save_kill_keeps_previous_checkpoint(report):
    """The kill lands inside the SECOND checkpoint's write, so the first
    (step 2) must stay the latest complete one, and the partial write must
    be visible only as a stray tmp dir."""
    entry = report["crash_resume"]["mid_save"]
    assert entry["latest_step"] == 2, entry
    assert entry["stray_tmp_dirs"] >= 1, entry


@pytest.mark.parametrize("case", ["mid_training", "mid_save"])
def test_resume_same_mesh_is_bit_exact(report, case):
    """A killed run resumed on the same data=8 mesh continues with
    loss/metric history BIT-EXACT vs an uninterrupted reference run, from
    the restored step through the end (full state round-trips: params,
    LAMB moments, step counter, data position)."""
    res = report["crash_resume"][case]["resume_same_mesh"]
    assert res["resumed_rows"] > 0, res
    assert res["steps_match"], res
    assert res["bitexact"], res
    assert res["loss_maxdiff"] == 0.0, res
    assert res["final_step"] == 8, res
    assert res["examples_seen_match"], res


def test_resume_other_mesh_shape(report):
    """The same crashed run resumes on a data=4,model=2 mesh: steps and
    examples_seen exact, loss within the cross-mesh reduction-order
    tolerance used by the equivalence suite."""
    res = report["crash_resume"]["mid_training"]["resume_other_mesh"]
    assert res["steps_match"], res
    assert res["loss_maxdiff"] < LOSS_TOL, res
    assert res["final_step"] == 8, res
    assert res["examples_seen_match"], res


@pytest.mark.parametrize("case", ["mid_training", "mid_save"])
def test_resume_garbage_collects_tmp_dirs(report, case):
    """The resumed run's first save must GC the crashed writer's debris,
    and its own checkpoints must advance LATEST to the final step."""
    res = report["crash_resume"][case]["resume_same_mesh"]
    assert res["tmp_gc_after_resume"], res
    assert res["final_latest_step"] == 8, res


# ---------------------------------------------------------------------------
# numerical faults & preemption (nan_skip / spike_rollback / sigterm_resume)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", ["data=8,model=1", "data=4,model=2"])
def test_nan_skip_matches_clean_run_bitwise(report, mesh):
    """With the non-finite guard on, a NaN-poisoned batch is skipped
    in-graph: params AND moments must be BITWISE equal to a run whose
    stream omits that ordinal — the skip verdict is a global reduction,
    so every device agrees and the select is a true no-op."""
    entry = report["nan_skip"][mesh]
    assert entry["skipped"] == 1, entry
    assert entry["param_maxdiff"] == 0.0, entry
    assert entry["moment_maxdiff"] == 0.0, entry
    assert entry["steps_match"], entry


@pytest.mark.parametrize("mesh", ["data=8,model=1", "data=4,model=2"])
def test_spike_rollback_recovers(report, mesh):
    """An injected loss spike trips the watchdog: exactly one rollback to
    the last validated checkpoint, the suspect window is dropped (step
    arithmetic proves no batch is silently retrained), and the run ends
    ok with finite loss."""
    entry = report["spike_rollback"][mesh]
    assert entry["rollbacks"] == 1, entry
    assert entry["reason"] == "loss_spike", entry
    assert entry["restored_step"] < entry["from_step"], entry
    assert entry["step_arithmetic_ok"], entry
    assert entry["final_loss_finite"], entry
    assert entry["status"] == "ok", entry


def test_sigterm_preemption_resumes_bit_exact(report):
    """SIGTERM mid-run: the victim saves inside the grace window, exits
    cleanly with status=preempted, and a --resume run continues BIT-EXACT
    vs an uninterrupted reference."""
    entry = report["sigterm_resume"]
    assert entry["preempt_status"] == "preempted", entry
    assert entry["stopped_early"], entry
    assert entry["saved_at_preempt_step"], entry
    assert entry["resumed_rows"] > 0, entry
    assert entry["bitexact"], entry
    assert entry["final_step"] == 8, entry
    assert entry["resume_status"] == "ok", entry


def test_fsdp_shrinks_per_device_state_memory(report):
    """Params + LAMB moments per device must shrink ≥4× under data=8 FSDP
    (measured ~8× — replicated scalars keep it from exactly N×)."""
    mem = report["memory"]
    assert mem["state_ratio"] >= 4.0, mem
    cs, cb = mem["compiled_sharded"], mem["compiled_single"]
    if "argument_bytes" in cs and "argument_bytes" in cb:
        # compiled per-device argument footprint (state + batch slice) must
        # shrink too; batch bytes are shared so the bound is looser
        assert cs["argument_bytes"] * 2 < cb["argument_bytes"], mem


def test_non_divisible_batches_raise(report):
    g = report["guards"]
    assert g["pipeline_raises"], g
    assert "divisible" in g["pipeline_msg"]
    assert g["trainer_raises"], g
    assert "divisible" in g["trainer_msg"]


# ---------------------------------------------------------------------------
# in-process unit checks (no devices needed)
# ---------------------------------------------------------------------------

def test_pallas_spec_ok_gates_sharded_leaves():
    from jax.sharding import PartitionSpec as P

    from repro.kernels import pallas_spec_ok

    assert pallas_spec_ok(None)
    assert pallas_spec_ok(P())
    assert pallas_spec_ok(P(None, None))
    assert not pallas_spec_ok(P("data"))
    assert not pallas_spec_ok(P(None, ("pod", "data")))
    assert not pallas_spec_ok(P(None, "model"))


@pytest.mark.parametrize("mode", ["pallas", "interpret"])
def test_fused_lamb_apply_sharded_specs_fall_back_to_xla(mode):
    """Kernel-path modes (pallas AND interpret) with fully sharded specs
    must run on CPU: every leaf takes the per-leaf XLA fallback, so the
    single-device-layout kernel is never launched on a sharded leaf."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.kernels import fused_lamb_apply

    params = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    grads = jax.tree.map(jnp.ones_like, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    specs = {"w": P("data", None), "b": P("data")}
    x_kern, _, _ = fused_lamb_apply(
        params, grads, zeros, zeros, jnp.asarray(1), jnp.asarray(1e-3),
        mode=mode, param_specs=specs,
    )
    x_xla, _, _ = fused_lamb_apply(
        params, grads, zeros, zeros, jnp.asarray(1), jnp.asarray(1e-3),
        mode="xla",
    )
    for a, b in zip(jax.tree.leaves(x_kern), jax.tree.leaves(x_xla)):
        assert jnp.allclose(a, b)


def test_parse_mesh_spec():
    from repro.launch.mesh import parse_mesh_spec

    assert parse_mesh_spec("data=4,model=2") == {"data": 4, "model": 2}
    assert parse_mesh_spec("pod=2, data=8, model=4") == {
        "pod": 2, "data": 8, "model": 4
    }
    for bad in ("data", "data=x", "data=0", "data=2,data=4", "=4"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


def test_make_host_mesh_rejects_bad_model_parallel():
    from repro.launch.mesh import make_host_mesh

    with pytest.raises(ValueError, match="divisor of"):
        make_host_mesh(3)  # 1 CPU device in-process: 1 % 3 != 0


def test_mesh_spec_too_many_devices():
    from repro.launch.mesh import make_mesh_from_spec

    with pytest.raises(ValueError, match="devices"):
        make_mesh_from_spec("data=64,model=64")


def test_train_state_shardings_structure():
    """Moments mirror their parameter's sharding; scalars replicate."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import TrainConfig
    from repro.models import build_model
    from repro.sharding import train_state_shardings
    from repro.train.step import make_train_step

    from tests.conftest import tiny_dense

    model = build_model(tiny_dense())
    tc = TrainConfig(optimizer="lamb", use_fused_lamb=True)
    init_fn, _ = make_train_step(model, tc)
    abstract = jax.eval_shape(init_fn, jax.random.key(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ssh = train_state_shardings(model.defs, abstract, mesh)
    assert ssh.step.spec == P()
    assert ssh.opt_state.count.spec == P()
    assert ssh.opt_state.mu["embed"] == ssh.params["embed"]
    assert ssh.opt_state.nu["blocks"]["attn"]["wq"] == (
        ssh.params["blocks"]["attn"]["wq"]
    )
