"""End-to-end system tests: data determinism, checkpoint roundtrip,
mixed-batch staging, training convergence, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import get_config, smoke_config
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import DataPipeline, SyntheticLM, batch_iterator, make_batch
from repro.models import build_model
from repro.serve import Engine, Request
from repro.train import Trainer, make_train_step
from tests.conftest import tiny_dense


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_across_runs():
    cfg = tiny_dense()
    it1 = batch_iterator(cfg, 4, 16, seed=7)
    it2 = batch_iterator(cfg, 4, 16, seed=7)
    for _ in range(3):
        b1, b2 = next(it1), next(it2)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])


def test_data_host_sharding_disjoint():
    cfg = tiny_dense()
    full = next(batch_iterator(cfg, 4, 16, seed=3, host_index=0, host_count=1))
    h0 = next(batch_iterator(cfg, 4, 16, seed=3, host_index=0, host_count=2))
    h1 = next(batch_iterator(cfg, 4, 16, seed=3, host_index=1, host_count=2))
    assert h0["tokens"].shape[0] == 2 and h1["tokens"].shape[0] == 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_lm_labels_are_next_tokens():
    cfg = tiny_dense()
    src = SyntheticLM(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    from repro.data import lm_batch

    b = lm_batch(src, rng, 2, 16)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


def test_mlm_masking_stats():
    cfg = get_config("bert-large").replace(vocab_size=512)
    rng = np.random.default_rng(0)
    b = make_batch(cfg, rng, 8, 128)
    frac = (b["labels"] >= 0).mean()
    assert 0.10 < frac < 0.22  # ~15% masked
    # corrupted at [MASK]=3 for ~80% of targets
    sel = b["labels"] >= 0
    mask_frac = (b["tokens"][sel] == 3).mean()
    assert 0.6 < mask_frac < 0.95


def test_audio_batch_learnable_targets():
    cfg = smoke_config("hubert-xlarge")
    rng = np.random.default_rng(0)
    b = make_batch(cfg, rng, 2, 32)
    assert b["frame_embeds"].shape == (2, 32, cfg.d_model)
    assert b["labels"].max() < cfg.vocab_size
    assert b["mask"].any()


def test_zipf_marginals_are_skewed():
    src = SyntheticLM(512, seed=0)
    toks = src.tokens(np.random.default_rng(0), 8, 256)
    counts = np.bincount(toks.ravel(), minlength=512)
    top = np.sort(counts)[::-1]
    # markov mixing flattens the aggregate marginal, but it must remain far
    # from uniform (uniform top-16 share = 16/512 ≈ 3.1%)
    assert top[:16].sum() > 0.08 * counts.sum()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_with_opt_state(key):
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(key)
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, np.random.default_rng(0), 2, 16))
    state, _ = jax.jit(step_fn)(state, batch)

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, state)
        target = jax.eval_shape(lambda: state)
        restored = restore_checkpoint(latest_checkpoint(d), target)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(key):
    params = {"w": jnp.ones((4, 4))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 0, params)
        bad = {"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)}
        with pytest.raises(ValueError):
            restore_checkpoint(latest_checkpoint(d), bad)


# ---------------------------------------------------------------------------
# training end-to-end
# ---------------------------------------------------------------------------

def test_lamb_training_decreases_loss():
    """Fixed-batch memorization: loss must fall fast under LAMB."""
    import itertools

    cfg = tiny_dense(n_layers=2, d_model=128, vocab_size=256)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-2)
    sched = core.warmup_poly_decay(1e-2, 60, 6)
    tr = Trainer(model, tc, schedule=sched, log_every=1, log_fn=lambda s: None)
    batch = make_batch(cfg, np.random.default_rng(0), 8, 32)
    hist = tr.fit(itertools.repeat(batch), 60)
    first, last = hist[0]["loss/total"], hist[-1]["loss/total"]
    assert last < first - 0.5, (first, last)


def test_microbatched_grads_match_full_batch(key):
    cfg = tiny_dense(activation_dtype="float32")
    model = build_model(cfg)
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16))
    tc_full = TrainConfig(optimizer="lamb", learning_rate=1e-3, grad_clip_norm=None)
    tc_micro = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                           grad_clip_norm=None, microbatch=2)
    i1, s1 = make_train_step(model, tc_full)
    i2, s2 = make_train_step(model, tc_micro)
    st1, st2 = i1(key), i2(key)
    st1b, m1 = jax.jit(s1)(st1, batch)
    st2b, m2 = jax.jit(s2)(st2, batch)
    for a, b in zip(jax.tree.leaves(st1b.params), jax.tree.leaves(st2b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_mixed_batch_stages_rewarmup():
    """fit_stages switches (seq, batch) shapes and re-warms up stage 2."""
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    stages = [
        core.make_stage("s1", 16, 8, 6, base_lr=1e-3, base_batch=8,
                        base_warmup_ratio=0.25),
        core.make_stage("s2", 32, 4, 6, base_lr=1e-3, base_batch=8,
                        base_warmup_ratio=0.25),
    ]
    tr = Trainer(model, tc, log_every=1, log_fn=lambda s: None)
    hist = tr.fit_stages(stages)
    assert int(tr.state.step) == 12
    assert any(h.get("stage") == 1 for h in hist)
    # moments carried across stages: second stage starts from trained params
    assert np.isfinite(hist[-1]["loss/total"])


def test_trust_ratio_logging():
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, log_trust_ratios=True)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, np.random.default_rng(0), 2, 16))
    _, metrics = jax.jit(step_fn)(state, batch)
    assert "trust_ratio/mean" in metrics
    assert float(metrics["trust_ratio/min"]) > 0


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_engine_greedy_deterministic(key):
    cfg = tiny_dense()
    model = build_model(cfg)
    params = model.init(key)
    eng = Engine(model, params, max_len=48)
    prompts = [np.arange(4, dtype=np.int32), np.arange(6, dtype=np.int32)]
    r1 = eng.generate_batch([Request(p, max_new_tokens=6) for p in prompts])
    r2 = eng.generate_batch([Request(p, max_new_tokens=6) for p in prompts])
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.out_tokens, b.out_tokens)


def test_engine_decode_matches_forward(key):
    """Greedy engine's first generated token == argmax of plain forward."""
    cfg = tiny_dense(activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    prompt = np.arange(8, dtype=np.int32)
    logits, _ = model.apply(params, {"tokens": jnp.asarray(prompt)[None]})
    want = int(jnp.argmax(logits[0, -1]))
    eng = Engine(model, params, max_len=32)
    out = eng.generate_batch([Request(prompt, max_new_tokens=1)])
    assert int(out[0].out_tokens[0]) == want
