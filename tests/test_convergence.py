"""Convergence-harness tier: the bench protocol is deterministic.

The multi-device work runs in ONE subprocess (tests/convergence_harness.py,
which forces 8 virtual CPU devices before importing jax — same pattern as
tests/sharded_harness.py).  The module-scoped fixture runs every scenario
once; the tests assert on slices of its JSON report, plus a few in-process
unit checks on the pure protocol helpers that need no devices.
"""
import json
import math
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)  # benchmarks.* (tests run with PYTHONPATH=src)

# Same global batch, different layout (mesh shape / accum split): only
# reduction-order noise is allowed to move the logged loss trajectory.
LOSS_TOL = 1e-2


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # the harness sets its own device count
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "convergence_harness.py")],
        capture_output=True, text=True, timeout=1800, cwd=REPO, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.splitlines()[-1])


@pytest.mark.slow
def test_harness_sees_8_devices(report):
    assert report["devices"] == 8


@pytest.mark.slow
def test_stream_is_pure_function_of_seed(report):
    s = report["stream"]
    assert s["same_seed_bitwise"], s
    assert s["diff_seed_differs"], s
    assert s["fields"] == ["labels", "tokens"]


@pytest.mark.slow
def test_trajectory_bitwise_reproducible(report):
    assert report["seed_stability"]["rerun_bitwise"]


@pytest.mark.slow
@pytest.mark.parametrize("variant", [
    "data=4,model=2|accum1",   # mesh shape
    "data=8,model=1|accum2",   # accumulation split
    "data=4,model=2|accum2",   # both
])
def test_trajectory_stable_across_mesh_and_accum(report, variant):
    """The convergence bench's steps-to-target must measure the optimizer,
    not the batch layout: re-sharding or re-chunking the same global batch
    may only move the logged losses by reduction noise."""
    v = report["seed_stability"]["variants"][variant]
    assert v["steps_match"], v
    assert v["loss_maxdiff"] < LOSS_TOL, v


@pytest.mark.slow
def test_trajectory_moves_with_data_seed(report):
    assert report["seed_stability"]["diff_seed_differs"]


@pytest.mark.slow
def test_steps_to_target_consistent_with_trajectory(report):
    t = report["target"]
    assert t["consistent"], t
    assert t["first_row_is_own_crossing"], t
    assert t["unreachable_is_none"], t
    assert t["history_len"] == 5, t


@pytest.mark.slow
def test_two_stage_rewarmup_runs_on_mesh(report):
    ts = report["two_stage"]
    assert ts["stages_seen"] == [0, 1], ts
    assert ts["stage2_rows"] == 3, ts
    assert ts["final_step"] == ts["total_steps"] == 6, ts
    assert ts["final_loss_finite"] and ts["eval_loss_finite"], ts


# ---------------------------------------------------------------------------
# in-process checks on the pure protocol helpers (no devices needed)
# ---------------------------------------------------------------------------

def test_steps_to_target_first_crossing():
    from benchmarks import protocol

    hist = [{"step": 1, "loss/total": 5.0}, {"step": 2, "loss/total": 4.0},
            {"step": 3, "loss/total": 4.2}]
    assert protocol.steps_to_target(hist, 4.5) == 2   # first crossing wins
    assert protocol.steps_to_target(hist, 5.0) == 1   # ≤ is inclusive
    assert protocol.steps_to_target(hist, 3.0) is None
    assert protocol.steps_to_target([], 1.0) is None


def test_recipe_sqrt_and_warmup_scaling():
    from benchmarks import protocol

    base = protocol.recipe("lamb", 8, base_batch=8, base_warmup_ratio=1 / 320)
    big = protocol.recipe("lamb", 512, base_batch=8, base_warmup_ratio=1 / 320)
    assert math.isclose(base["lr"], protocol.UNTUNED_BASE_LR["lamb"])
    assert math.isclose(big["lr"], base["lr"] * 8.0)       # sqrt(64×)
    assert math.isclose(base["warmup_ratio"], 1 / 320)
    assert math.isclose(big["warmup_ratio"], 64 / 320)     # linear-epoch
    capped = protocol.recipe("lamb", 512, base_batch=8, base_warmup_ratio=1 / 40)
    assert capped["warmup_ratio"] == 1.0                   # clips at 1


def test_make_train_config_gates_fused_lamb():
    from benchmarks import protocol

    assert protocol.make_train_config("lamb", 1e-3).use_fused_lamb
    assert not protocol.make_train_config("lans", 1e-3).use_fused_lamb
    assert not protocol.make_train_config("adamw", 1e-3).use_fused_lamb
    assert not protocol.make_train_config("lamb", 1e-3, fused=False).use_fused_lamb
