"""Fused MLM head: chunked-vocab CE kernel + gather vs the dense oracle.

Three altitudes, mirroring the flash-attention suite:

  * kernel — ``kernels.fused_ce`` (interpret + xla backends) vs the dense
    ``fused_ce_ref`` oracle, values and ``jax.grad`` cotangents;
  * loss — ``fused_cross_entropy`` (gather + kernel) vs ``cross_entropy``
    on dense logits, including degenerate supervision (all-IGNORE, overflow);
  * model — ``make_loss_fn(use_fused_ce=True)`` vs the dense head through a
    real bert-family model: loss, accuracy and full param/embedding grads,
    across {fp32, bf16} × {partial, full, zero supervision} × backends, and
    one jitted end-to-end train step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.data.synthetic import SyntheticLM, mlm_batch
from repro.kernels import fused_ce
from repro.kernels.ref import fused_ce_ref
from repro.models import build_model
from repro.train.loss import (
    IGNORE,
    check_fused_ce_supported,
    cross_entropy,
    fused_cross_entropy,
    gather_supervised,
    mlm_buffer_size,
)
from repro.train.step import make_loss_fn, make_train_step

RNG = np.random.default_rng(7)

BACKENDS = ["interpret", "xla"]


def _rand(n, d, v, dtype=jnp.float32):
    h = jnp.asarray(RNG.standard_normal((n, d)), dtype)
    w = jnp.asarray(RNG.standard_normal((v, d)) * 0.3, dtype)
    lbl = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    return h, w, lbl


def _mini_cfg(**kw):
    kw.setdefault("activation_dtype", "float32")
    kw.setdefault("vocab_size", 256)
    return get_config("bert-large").replace(
        name="bert-fce-mini", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, **kw
    )


# ---------------------------------------------------------------------------
# kernel vs dense oracle
# ---------------------------------------------------------------------------

CE_SHAPES = [
    (48, 32, 300),    # ragged rows and vocab (padding paths)
    (17, 16, 64),     # rows < block, single vocab chunk
    (256, 64, 1000),  # multiple row blocks
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,d,v", CE_SHAPES)
def test_fused_ce_matches_ref(n, d, v, backend):
    h, w, lbl = _rand(n, d, v)
    kw = dict(interpret=True) if backend == "interpret" else dict(backend="xla")
    nll, correct = fused_ce(h, w, lbl, block_n=16, block_v=64, **kw)
    nll_r, correct_r = fused_ce_ref(h, w, lbl)
    np.testing.assert_allclose(np.asarray(nll), np.asarray(nll_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(correct), np.asarray(correct_r))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce_grad_matches_ref(backend, dtype):
    """jax.grad through the chunked kernel ≡ grad through dense logits,
    with varied per-row cotangents (incl. zeros — ignored rows)."""
    n, d, v = 48, 32, 300
    h, w, lbl = _rand(n, d, v, dtype)
    wts = jnp.asarray(RNG.random(n) > 0.3, jnp.float32) * jnp.asarray(
        RNG.random(n), jnp.float32)
    kw = dict(interpret=True) if backend == "interpret" else dict(backend="xla")

    def loss(h, w):
        nll, _ = fused_ce(h, w, lbl, block_n=16, block_v=64, **kw)
        return jnp.sum(nll * wts)

    def loss_ref(h, w):
        nll, _ = fused_ce_ref(h, w, lbl)
        return jnp.sum(nll * wts)

    gh, gw = jax.grad(loss, (0, 1))(h, w)
    gh_r, gw_r = jax.grad(loss_ref, (0, 1))(h, w)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gh, np.float32),
                               np.asarray(gh_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(gw, np.float32),
                               np.asarray(gw_r, np.float32), **tol)


def test_fused_ce_shape_guards():
    h, w, lbl = _rand(8, 16, 32)
    with pytest.raises(ValueError, match="feature dim"):
        fused_ce(h, jnp.zeros((32, 8)), lbl, backend="xla")
    with pytest.raises(ValueError, match="labels shape"):
        fused_ce(h, w, lbl[:4], backend="xla")
    with pytest.raises(ValueError, match="conflicts"):
        fused_ce(h, w, lbl, backend="xla", interpret=True)


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------

def test_gather_supervised_packs_and_masks():
    labels = jnp.asarray([
        [IGNORE, 5, IGNORE, 7, IGNORE, IGNORE],
        [IGNORE] * 6,
        [1, 2, 3, IGNORE, IGNORE, IGNORE],
    ], jnp.int32)
    hidden = jnp.arange(3 * 6, dtype=jnp.float32).reshape(3, 6, 1)
    h_sel, lbl_sel, valid, count = gather_supervised(hidden, labels, 3)
    assert h_sel.shape == (3, 3, 1) and lbl_sel.shape == (3, 3)
    np.testing.assert_array_equal(np.asarray(count), [2, 0, 3])
    # supervised positions first, original order, pads marked IGNORE/invalid
    np.testing.assert_array_equal(np.asarray(lbl_sel[0]), [5, 7, IGNORE])
    np.testing.assert_array_equal(np.asarray(h_sel[0, :2, 0]), [1.0, 3.0])
    np.testing.assert_array_equal(np.asarray(valid),
                                  [[1, 1, 0], [0, 0, 0], [1, 1, 1]])
    np.testing.assert_array_equal(np.asarray(lbl_sel[2]), [1, 2, 3])


def test_mlm_buffer_size_defaults():
    cfg = _mini_cfg()                       # mask_ratio = 0.15
    assert mlm_buffer_size(cfg, 128) == 20  # ceil(0.15 * 128)
    assert mlm_buffer_size(cfg.replace(mlm_max_predictions=8), 128) == 8
    assert mlm_buffer_size(cfg.replace(mask_ratio=0.0), 128) == 128


def test_mlm_batch_counts_stay_under_buffer():
    """The synthetic pipeline guarantees the fused head's gather bound:
    per-row target counts never exceed ceil(mask_ratio * seq), stay >= 1,
    and still vary row to row (token-weighted accumulation relies on it)."""
    src = SyntheticLM(512, seed=0)
    counts = []
    for i in range(8):
        b = mlm_batch(src, np.random.default_rng(i), 16, 128, 0.15)
        c = (b["labels"] >= 0).sum(axis=-1)
        assert c.max() <= int(np.ceil(0.15 * 128))
        assert c.min() >= 1
        counts.extend(c.tolist())
    assert len(set(counts)) > 1
    b = mlm_batch(src, np.random.default_rng(0), 8, 128, 0.15,
                  max_predictions=5)
    assert (b["labels"] >= 0).sum(axis=-1).max() <= 5


# ---------------------------------------------------------------------------
# loss level: fused_cross_entropy vs dense cross_entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_cross_entropy_matches_dense(backend):
    b, s, d, v = 3, 24, 16, 120
    hidden = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((v, d)) * 0.3, jnp.float32)
    labels = np.full((b, s), IGNORE, np.int32)
    sel = RNG.random((b, s)) < 0.3
    sel[:, 0] = True
    labels[sel] = RNG.integers(0, v, (b, s))[sel]
    labels = jnp.asarray(labels)

    def dense(hidden, w):
        return cross_entropy(jnp.einsum("bsd,vd->bsv", hidden, w), labels)

    def fused(hidden, w):
        return fused_cross_entropy(hidden, labels, w, max_positions=s,
                                   backend=backend)

    (l_f, a_f), (l_d, a_d) = fused(hidden, w), dense(hidden, w)
    assert float(l_f) == pytest.approx(float(l_d), rel=1e-5)
    assert float(a_f) == pytest.approx(float(a_d))
    g_f = jax.grad(lambda *a: fused(*a)[0], (0, 1))(hidden, w)
    g_d = jax.grad(lambda *a: dense(*a)[0], (0, 1))(hidden, w)
    for a, bb in zip(g_f, g_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_cross_entropy_zero_supervision(backend):
    """All-IGNORE batch: finite zero loss and exactly zero grads (matching
    the dense path's max(denom, 1) convention)."""
    b, s, d, v = 2, 16, 8, 64
    hidden = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
    labels = jnp.full((b, s), IGNORE, jnp.int32)

    loss, acc = fused_cross_entropy(hidden, labels, w, max_positions=4,
                                    backend=backend)
    assert float(loss) == 0.0 and float(acc) == 0.0
    gh, gw = jax.grad(
        lambda *a: fused_cross_entropy(a[0], labels, a[1], max_positions=4,
                                       backend=backend)[0], (0, 1)
    )(hidden, w)
    assert float(jnp.max(jnp.abs(gh))) == 0.0
    assert float(jnp.max(jnp.abs(gw))) == 0.0

    l_d, a_d = cross_entropy(jnp.einsum("bsd,vd->bsv", hidden, w), labels)
    assert float(l_d) == 0.0 and float(a_d) == 0.0


def test_fused_cross_entropy_overflow_raises_eagerly():
    b, s, d, v = 2, 16, 8, 64
    hidden = jnp.zeros((b, s, d), jnp.float32)
    w = jnp.zeros((v, d), jnp.float32)
    labels = jnp.zeros((b, s), jnp.int32)   # all 16 positions supervised
    with pytest.raises(ValueError, match="silently truncate"):
        fused_cross_entropy(hidden, labels, w, max_positions=4)


def test_fused_cross_entropy_overflow_poisons_under_jit():
    """Inside jit the eager ValueError is unreachable: the loss AND its
    gradients must come back NaN (loud) — never a silently-truncated finite
    value, and never finite zero grads next to a NaN loss."""
    b, s, d, v = 2, 16, 8, 64
    hidden = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)

    @jax.jit
    def f(labels, hidden, w):
        return fused_cross_entropy(hidden, labels, w, max_positions=4)[0]

    over = jnp.zeros((b, s), jnp.int32)                           # 16 > 4
    assert np.isnan(float(f(over, hidden, w)))
    # accuracy poisons too: it would otherwise be a finite, plausible value
    # computed over only the first P gathered positions
    assert np.isnan(float(jax.jit(
        lambda l: fused_cross_entropy(hidden, l, w, max_positions=4)[1]
    )(over)))
    gh, gw = jax.jit(jax.grad(f, (1, 2)))(over, hidden, w)
    assert np.isnan(np.asarray(gh)).any() and np.isnan(np.asarray(gw)).any()
    ok = np.full((b, s), IGNORE, np.int32)
    ok[:, :3] = 1
    assert np.isfinite(float(f(jnp.asarray(ok), hidden, w)))      # 3 <= 4
    gh, gw = jax.jit(jax.grad(f, (1, 2)))(jnp.asarray(ok), hidden, w)
    assert np.isfinite(np.asarray(gh)).all() and np.isfinite(np.asarray(gw)).all()


def test_fused_ce_unsupported_configs_raise():
    cfg = _mini_cfg()
    with pytest.raises(ValueError, match="logit_softcap"):
        check_fused_ce_supported(cfg.replace(logit_softcap=30.0))
    with pytest.raises(ValueError, match="family"):
        check_fused_ce_supported(cfg.replace(family="hybrid"))
    model = build_model(cfg.replace(logit_softcap=30.0))
    with pytest.raises(ValueError, match="logit_softcap"):
        make_loss_fn(model, use_fused_ce=True)
    # Bernoulli span masks (hubert) are not bounded by ceil(mask_ratio*S):
    # the fused head demands an explicit buffer size there
    audio = cfg.replace(frontend="audio_stub", mask_ratio=0.08)
    with pytest.raises(ValueError, match="mlm_max_predictions"):
        check_fused_ce_supported(audio)
    check_fused_ce_supported(audio.replace(mlm_max_predictions=32))


def test_make_batch_cap_tracks_fused_buffer():
    """make_batch floors the masking rate at 0.15, but its cap must come
    from the same mlm_buffer_size the fused head uses — a config with
    0 < mask_ratio < 0.15 must still never exceed the gather buffer."""
    cfg = _mini_cfg(mask_ratio=0.10)
    s = 128
    buf = cfg.mlm_buffer_size(s)
    assert buf == 13   # ceil(0.10 * 128), not ceil(0.15 * 128)
    for i in range(4):
        b = make_batch(cfg, np.random.default_rng(i), 16, s)
        assert (b["labels"] >= 0).sum(axis=-1).max() <= buf


# ---------------------------------------------------------------------------
# model level: fused head ≡ dense head through a real bert-family model
# ---------------------------------------------------------------------------

def _batch_for(cfg, supervision, b=4, s=32):
    if supervision == "partial":
        return make_batch(cfg, np.random.default_rng(0), b, s), cfg
    src = SyntheticLM(cfg.vocab_size, seed=0)
    toks = src.tokens(np.random.default_rng(1), b, s)
    if supervision == "full":
        # every position supervised: the buffer must be widened to S
        return {"tokens": toks, "labels": toks.copy()}, cfg.replace(
            mlm_max_predictions=s)
    labels = np.full((b, s), IGNORE, np.int32)
    return {"tokens": toks, "labels": labels}, cfg


@pytest.mark.parametrize("supervision", ["partial", "full", "zero"])
@pytest.mark.parametrize("act_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_head_matches_dense_model(supervision, act_dtype, backend):
    """loss / accuracy / full param + embedding grads: fused ≡ dense."""
    cfg = _mini_cfg(activation_dtype=act_dtype, fused_ce_backend=backend)
    raw, cfg = _batch_for(cfg, supervision)
    batch = jax.tree.map(jnp.asarray, raw)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    out = {}
    for fused in (True, False):
        loss_fn = make_loss_fn(model, use_fused_ce=fused)
        (loss, metrics), grads = jax.jit(
            jax.value_and_grad(loss_fn, has_aux=True)
        )(params, batch)
        out[fused] = (float(loss), float(metrics["accuracy"]), grads)

    l_f, a_f, g_f = out[True]
    l_d, a_d, g_d = out[False]
    assert np.isfinite(l_f) and np.isfinite(l_d)
    bf16 = act_dtype == "bfloat16"
    assert l_f == pytest.approx(l_d, rel=2e-2 if bf16 else 1e-5, abs=1e-6)
    # bf16 rounds the dense logits before its fp32 softmax while the fused
    # path keeps the fp32 product — near-tie argmaxes may flip a position
    assert a_f == pytest.approx(a_d, abs=0.1 if bf16 else 1e-6)
    tol = dict(rtol=5e-2, atol=3e-2) if bf16 else dict(rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(g_f), jax.tree.leaves(g_d)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)
    if supervision == "zero":
        assert l_f == 0.0
        for a in jax.tree.leaves(g_f):
            assert float(jnp.max(jnp.abs(a))) == 0.0


def test_fused_head_respects_compute_dtype_cast():
    """make_loss_fn(compute_dtype=...) must cast the vocab projection the
    fused head uses, not just the forward — fused ≡ dense under the same
    bf16 policy (both heads projecting the bf16-cast table)."""
    cfg = _mini_cfg()
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 32)
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    losses = {}
    for fused in (True, False):
        loss_fn = make_loss_fn(model, "bfloat16", use_fused_ce=fused)
        losses[fused] = float(loss_fn(params, batch)[0])
    assert losses[True] == pytest.approx(losses[False], rel=2e-2)


def test_train_step_fused_ce_equals_dense():
    """End-to-end: one jitted train step with the fused head reproduces the
    dense head's loss, metrics and updated params (CPU: XLA CE backend)."""
    base = _mini_cfg()
    batch = jax.tree.map(
        jnp.asarray, make_batch(base, np.random.default_rng(0), 4, 64)
    )
    key = jax.random.key(0)
    states, metrics = [], []
    for fused in (True, False):
        cfg = base.replace(use_fused_ce_head=fused)
        model = build_model(cfg)
        tc = TrainConfig(optimizer="lamb", grad_clip_norm=None)
        init_fn, step_fn = make_train_step(model, tc)
        st, m = jax.jit(step_fn)(init_fn(key), batch)
        states.append(st)
        metrics.append(m)
    assert float(metrics[0]["loss/total"]) == pytest.approx(
        float(metrics[1]["loss/total"]), rel=1e-5)
    assert float(metrics[0]["accuracy"]) == pytest.approx(
        float(metrics[1]["accuracy"]))
    assert float(metrics[0]["grad_norm"]) == pytest.approx(
        float(metrics[1]["grad_norm"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(states[0].params),
                    jax.tree.leaves(states[1].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_fused_head_compiled_without_logits_tensor():
    """The jitted fused loss must contain no (B, S, V) tensor of any dtype
    (the benchmark asserts the same on the full train step's HLO)."""
    cfg = _mini_cfg(vocab_size=3001)   # unique dim: unambiguous in HLO text
    raw, cfg = _batch_for(cfg, "partial")
    batch = jax.tree.map(jnp.asarray, raw)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, s = batch["labels"].shape
    for fused, expect in ((True, False), (False, True)):
        loss_fn = make_loss_fn(model, use_fused_ce=fused)
        text = jax.jit(loss_fn).lower(params, batch).compile().as_text()
        assert (f"[{b},{s},{cfg.vocab_size}]" in text) is expect, (
            f"fused={fused}: unexpected (B,S,V) presence")
