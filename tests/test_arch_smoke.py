"""Per-assigned-architecture smoke tests: a REDUCED variant of each family
(≤2-4 layers, d_model ≤ 512, ≤4 experts) runs one forward + one LAMB train
step on CPU; output shapes asserted, no NaNs anywhere."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.models import build_model
from repro.train import make_train_step

ALL = ARCHS + ["bert-large"]


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    return jax.tree.map(jnp.asarray, make_batch(cfg, rng, b, s))


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch)
    b = batch.get("tokens", batch.get("frame_embeds")).shape[0]
    s = 16
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL)
def test_one_lamb_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, grad_clip_norm=1.0)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(jax.random.key(0))
    batch = _batch(cfg)
    state2, metrics = jax.jit(step_fn)(state, batch)
    assert int(state2.step) == 1
    assert np.isfinite(float(metrics["loss/total"]))
    # params moved and stayed finite
    moved = False
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params)):
        assert bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))
        moved = moved or bool(jnp.any(a != b))
    assert moved


@pytest.mark.parametrize(
    "arch", [a for a in ALL if a not in ("hubert-xlarge", "bert-large")]
)
def test_prefill_then_decode_consistency(arch):
    """prefill(s tokens) + decode(token s) ≡ full forward on s+1 tokens.

    The strongest cache-correctness test: exercises every family's cache
    (KV / MLA latent / mamba state / mLSTM matrix memory).  MoE capacity is
    raised so no token drops (drops are position-competition dependent and
    would legitimately differ between the two paths)."""
    cfg = smoke_config(arch).replace(
        activation_dtype="float32", capacity_factor=8.0
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    s = 12
    batch = jax.tree.map(jnp.asarray,
                         __import__("repro.data", fromlist=["make_batch"]).make_batch(
                             cfg, rng, 2, s + 1))
    batch.pop("labels", None)

    # full forward over s+1 tokens
    logits_full, _ = model.apply(params, batch)

    # prefill on first s, then decode token s
    if cfg.frontend == "vision_stub":
        pre = {"tokens": batch["tokens"][:, :-1], "image_embeds": batch["image_embeds"]}
        npref = cfg.n_prefix_tokens
        total_prefill = s + npref - 1 + 1  # image + all-but-last text
        last_tok = batch["tokens"][:, -1:]
        pos = jnp.full((2, 1), batch["tokens"].shape[1] - 1 + npref, jnp.int32)
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1:]
        pos = jnp.full((2, 1), s, jnp.int32)

    cache = model.make_cache(2, s + 8)
    logits_pre, cache = model.prefill(params, pre, cache)
    logits_dec, _ = model.decode(params, {"tokens": last_tok}, cache, pos)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]),
        np.asarray(logits_full[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("arch", ["smollm-360m", "granite-moe-1b-a400m"])
def test_sliding_window_variant_runs(arch):
    cfg = smoke_config(arch).replace(sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels", None)
    logits, _ = model.apply(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_deepseek_mtp_smoke():
    cfg = smoke_config("deepseek-v3-671b").replace(use_mtp=True)
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(jax.random.key(0))
    batch = _batch(cfg)
    state2, metrics = jax.jit(step_fn)(state, batch)
    assert "loss/mtp" in metrics
    assert np.isfinite(float(metrics["loss/total"]))


def test_mla_absorbed_equals_naive():
    cfg = smoke_config("deepseek-v3-671b").replace(activation_dtype="float32")
    model_n = build_model(cfg)
    model_a = build_model(cfg.replace(mla_absorb=True))
    params = model_n.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels", None)
    ln, _ = model_n.apply(params, batch)
    la, _ = model_a.apply(params, batch)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(la), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["smollm-360m", "jamba-1.5-large-398b", "xlstm-350m"])
def test_unrolled_equals_scanned(arch):
    """cfg.scan_layers=False (the dry-run's cost-accounting lowering) is
    mathematically identical to the scanned production path."""
    cfg = smoke_config(arch).replace(activation_dtype="float32")
    m_scan = build_model(cfg)
    m_unrl = build_model(cfg.replace(scan_layers=False))
    params = m_scan.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels", None)
    l1, _ = m_scan.apply(params, batch)
    l2, _ = m_unrl.apply(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-5, atol=2e-5)


def test_jamba_model_level_chunked_scan():
    cfg = smoke_config("jamba-1.5-large-398b").replace(activation_dtype="float32")
    m1 = build_model(cfg)
    m2 = build_model(cfg.replace(mamba_chunk=4))
    params = m1.init(jax.random.key(0))
    batch = _batch(cfg)
    batch.pop("labels", None)
    l1, _ = m1.apply(params, batch)
    l2, _ = m2.apply(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)
