"""Pallas kernels vs their pure-jnp oracles (interpret mode): shape/dtype
sweeps per the per-kernel test requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, flash_sdpa, fused_lamb, lamb_update
from repro.kernels.ref import flash_attention_ref, lamb_update_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# fused LAMB
# ---------------------------------------------------------------------------

LAMB_SHAPES = [
    ((128,), None),
    ((1000,), None),            # non-multiple of block
    ((8, 16), None),
    ((4, 300), 0),              # stacked layers, ragged per-layer size
    ((2, 64, 32), 0),
    ((1, 9000), 0),
    ((3, 4096), 0),
]


@pytest.mark.parametrize("shape,axis", LAMB_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lamb_kernel_matches_ref(shape, axis, dtype):
    x = jnp.asarray(RNG.standard_normal(shape), dtype)
    g = jnp.asarray(RNG.standard_normal(shape), dtype)
    m = jnp.asarray(RNG.standard_normal(shape), jnp.float32) * 0.1
    v = jnp.abs(jnp.asarray(RNG.standard_normal(shape), jnp.float32)) * 0.01
    kw = dict(lr=0.01, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01)
    x1, m1, v1 = lamb_update(
        x, g, m, v, jnp.asarray(5), layer_axis=axis, interpret=True, **kw
    )
    x2, m2, v2 = lamb_update_ref(x, g, m, v, step=5, layer_axis=axis, **kw)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=3e-5, atol=1e-6)


def test_lamb_kernel_phi_bounds_and_no_trust():
    shape = (2, 500)
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32) * 10
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    m = jnp.zeros(shape, jnp.float32)
    v = jnp.zeros(shape, jnp.float32)
    for kw in (dict(phi_bounds=(0.5, 2.0)), dict(apply_trust=False)):
        ref_kw = dict(lr=0.1, weight_decay=0.01, step=1, layer_axis=0, **kw)
        kern_kw = dict(lr=0.1, weight_decay=0.01, layer_axis=0, interpret=True)
        if "phi_bounds" in kw:
            kern_kw.update(phi_lo=kw["phi_bounds"][0], phi_hi=kw["phi_bounds"][1])
        else:
            kern_kw.update(apply_trust=False)
        x1, _, _ = lamb_update(x, g, m, v, jnp.asarray(1), **kern_kw)
        x2, _, _ = lamb_update_ref(x, g, m, v, **ref_kw)
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=3e-5, atol=3e-6)


def test_fused_lamb_transform_equals_core_lamb():
    from repro import core, optim

    params = {
        "stack": {"w": jnp.asarray(RNG.standard_normal((3, 24, 8)), jnp.float32)},
        "emb": jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32),
        "norm": jnp.ones((8,), jnp.float32),
    }
    la = {"stack": {"w": 0}, "emb": -1, "norm": -1}
    tm = {"stack": {"w": True}, "emb": True, "norm": False}
    wm = {"stack": {"w": True}, "emb": True, "norm": False}
    sched = core.warmup_poly_decay(0.01, 50, 5)
    o1 = core.lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                   wd_mask=wm)
    o2 = fused_lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                    wd_mask=wm, interpret=True)
    s1, s2 = o1.init(params), o2.init(params)
    p1 = p2 = params
    for t in range(4):
        g = jax.tree.map(
            lambda x: jnp.asarray(RNG.standard_normal(x.shape), jnp.float32), params
        )
        u1, s1 = o1.update(g, s1, p1)
        p1 = optim.apply_updates(p1, u1)
        u2, s2 = o2.update(g, s2, p2)
        p2 = optim.apply_updates(p2, u2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    (1, 2, 128, 128, 64, True),
    (2, 3, 256, 256, 32, True),
    (1, 1, 128, 384, 64, False),   # cross-length, non-causal
    (2, 2, 384, 384, 128, True),
]


@pytest.mark.parametrize("b,h,s,t,d,causal", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, s, t, d, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, h, t, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, h, t, d)), dtype)
    o1 = flash_attention(q, k, v, causal=causal, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


def test_flash_gqa_layout_wrapper():
    b, s, h, hkv, d = 2, 128, 8, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    o1 = flash_sdpa(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, h // hkv, axis=2)
    vr = jnp.repeat(v, h // hkv, axis=2)
    o2 = flash_attention_ref(
        q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
        vr.transpose(0, 2, 1, 3), causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5, atol=3e-5)


def test_flash_rejects_indivisible_blocks():
    q = jnp.zeros((1, 1, 100, 64))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)


@pytest.mark.parametrize("s,w", [(512, 128), (256, 64), (384, 256)])
def test_flash_attention_sliding_window(s, w):
    """Windowed flash kernel == dense-masked SWA reference.

    This is the kernel path that actually SAVES the SWA FLOPs by skipping
    out-of-window kv blocks (§Perf F1: a dense masked softmax saves none)."""
    q = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, window=w, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)
