"""Pallas kernels vs their pure-jnp oracles (interpret mode): shape/dtype
sweeps per the per-kernel test requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, flash_sdpa, fused_lamb, lamb_update
from repro.kernels.ref import flash_attention_ref, lamb_update_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# fused LAMB
# ---------------------------------------------------------------------------

LAMB_SHAPES = [
    ((128,), None),
    ((1000,), None),            # non-multiple of block
    ((8, 16), None),
    ((4, 300), 0),              # stacked layers, ragged per-layer size
    ((2, 64, 32), 0),
    ((1, 9000), 0),
    ((3, 4096), 0),
]


@pytest.mark.parametrize("shape,axis", LAMB_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lamb_kernel_matches_ref(shape, axis, dtype):
    x = jnp.asarray(RNG.standard_normal(shape), dtype)
    g = jnp.asarray(RNG.standard_normal(shape), dtype)
    m = jnp.asarray(RNG.standard_normal(shape), jnp.float32) * 0.1
    v = jnp.abs(jnp.asarray(RNG.standard_normal(shape), jnp.float32)) * 0.01
    kw = dict(lr=0.01, b1=0.9, b2=0.999, eps=1e-6, weight_decay=0.01)
    x1, m1, v1 = lamb_update(
        x, g, m, v, jnp.asarray(5), layer_axis=axis, interpret=True, **kw
    )
    x2, m2, v2 = lamb_update_ref(x, g, m, v, step=5, layer_axis=axis, **kw)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=3e-5, atol=1e-6)


def test_lamb_kernel_phi_bounds_and_no_trust():
    shape = (2, 500)
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32) * 10
    g = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    m = jnp.zeros(shape, jnp.float32)
    v = jnp.zeros(shape, jnp.float32)
    for kw in (dict(phi_bounds=(0.5, 2.0)), dict(apply_trust=False)):
        ref_kw = dict(lr=0.1, weight_decay=0.01, step=1, layer_axis=0, **kw)
        kern_kw = dict(lr=0.1, weight_decay=0.01, layer_axis=0, interpret=True)
        if "phi_bounds" in kw:
            kern_kw.update(phi_lo=kw["phi_bounds"][0], phi_hi=kw["phi_bounds"][1])
        else:
            kern_kw.update(apply_trust=False)
        x1, _, _ = lamb_update(x, g, m, v, jnp.asarray(1), **kern_kw)
        x2, _, _ = lamb_update_ref(x, g, m, v, **ref_kw)
        np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                                   rtol=3e-5, atol=3e-6)


def test_fused_lamb_transform_equals_core_lamb():
    from repro import core, optim

    params = {
        "stack": {"w": jnp.asarray(RNG.standard_normal((3, 24, 8)), jnp.float32)},
        "emb": jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32),
        "norm": jnp.ones((8,), jnp.float32),
    }
    la = {"stack": {"w": 0}, "emb": -1, "norm": -1}
    tm = {"stack": {"w": True}, "emb": True, "norm": False}
    wm = {"stack": {"w": True}, "emb": True, "norm": False}
    sched = core.warmup_poly_decay(0.01, 50, 5)
    o1 = core.lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                   wd_mask=wm)
    o2 = fused_lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                    wd_mask=wm, interpret=True)
    s1, s2 = o1.init(params), o2.init(params)
    p1 = p2 = params
    for t in range(4):
        g = jax.tree.map(
            lambda x: jnp.asarray(RNG.standard_normal(x.shape), jnp.float32), params
        )
        u1, s1 = o1.update(g, s1, p1)
        p1 = optim.apply_updates(p1, u1)
        u2, s2 = o2.update(g, s2, p2)
        p2 = optim.apply_updates(p2, u2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    (1, 2, 128, 128, 64, True),
    (2, 3, 256, 256, 32, True),
    (1, 1, 128, 384, 64, False),   # cross-length, non-causal
    (2, 2, 384, 384, 128, True),
]


@pytest.mark.parametrize("b,h,s,t,d,causal", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, s, t, d, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, h, t, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, h, t, d)), dtype)
    o1 = flash_attention(q, k, v, causal=causal, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


def test_flash_gqa_layout_wrapper():
    b, s, h, hkv, d = 2, 128, 8, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    o1 = flash_sdpa(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, h // hkv, axis=2)
    vr = jnp.repeat(v, h // hkv, axis=2)
    o2 = flash_attention_ref(
        q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3),
        vr.transpose(0, 2, 1, 3), causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=3e-5, atol=3e-5)


def test_flash_rejects_indivisible_blocks():
    q = jnp.zeros((1, 1, 100, 64))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)


@pytest.mark.parametrize("s,w", [(512, 128), (256, 64), (384, 256)])
def test_flash_attention_sliding_window(s, w):
    """Windowed flash kernel == dense-masked SWA reference.

    This is the kernel path that actually SAVES the SWA FLOPs by skipping
    out-of-window kv blocks (§Perf F1: a dense masked softmax saves none)."""
    q = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, s, 64)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, window=w, interpret=True)
    o2 = flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash attention backward (custom VJP vs jax.grad through the dense oracle)
# ---------------------------------------------------------------------------

def _grad_case(make_flash, make_ref, args, tol):
    """max-abs-compare outputs and (dq, dk, dv) cotangents of a loss."""

    def loss(f):
        return lambda *a: jnp.sum(jnp.sin(f(*a).astype(jnp.float32)))

    o1, o2 = make_flash(*args), make_ref(*args)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        rtol=tol, atol=tol)
    g1 = jax.grad(loss(make_flash), (0, 1, 2))(*args)
    g2 = jax.grad(loss(make_ref), (0, 1, 2))(*args)
    for name, a, b in zip("qkv", g1, g2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=tol, atol=tol, err_msg=f"d{name}")


# (b, h, hkv, s, d, causal, masked): causal/bidirectional × GQA × padding
FLASH_GRAD_CASES = [
    (1, 2, 2, 128, 32, True, False),
    (1, 2, 2, 128, 32, False, False),    # bidirectional (BERT MLM)
    (2, 4, 1, 128, 32, True, False),     # MQA
    (2, 4, 2, 128, 16, False, False),    # GQA bidirectional
    (2, 2, 2, 128, 32, False, True),     # padding mask, bidirectional
    (1, 4, 2, 256, 32, True, True),      # padding mask + GQA + causal
]


@pytest.mark.parametrize("b,h,hkv,s,d,causal,masked", FLASH_GRAD_CASES)
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_flash_grad_matches_ref(b, h, hkv, s, d, causal, masked, backend):
    """jax.grad through the flash custom-VJP ≡ grad through the dense
    softmax, for both the Pallas kernels (interpret) and the XLA scan."""
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
    valid = (
        jnp.asarray(RNG.integers(s // 2, s + 1, size=(b,)), jnp.int32)
        if masked else None
    )
    rep = lambda x: jnp.repeat(x, h // hkv, axis=1)
    _grad_case(
        lambda q, k, v: flash_attention(
            q, k, v, valid, causal=causal, backend=backend),
        lambda q, k, v: flash_attention_ref(
            q, rep(k), rep(v), valid, causal=causal),
        (q, k, v), tol=3e-5,
    )


def test_flash_grad_window():
    """Sliding-window backward: recompute masks match the forward's."""
    q = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 32)), jnp.float32)
    _grad_case(
        lambda q, k, v: flash_attention(
            q, k, v, causal=True, window=100, interpret=True),
        lambda q, k, v: flash_attention_ref(q, k, v, causal=True, window=100),
        (q, k, v), tol=3e-5,
    )


def test_flash_window_plus_valid_fully_masked_rows():
    """window ∩ valid can be empty for pad rows (row - window >= valid):
    flash yields o = 0 and zero grads there (p forced to 0, not
    exp(NEG_INF - NEG_INF) = 1), and matches the dense reference exactly on
    every row that still has >= 1 valid key."""
    b, h, s, d, w = 2, 2, 256, 32, 64
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, h, s, d)), jnp.float32)
    valid = jnp.asarray([40, s], jnp.int32)
    # causal+window row r attends (r-w, r] ∩ [0, valid): nonempty iff
    # r-w+1 <= valid-1, i.e. r <= valid + w - 2
    live = jnp.arange(s)[None, :] <= valid[:, None] + w - 2   # (b, s)
    lm = live[:, None, :, None].astype(jnp.float32)

    ref = flash_attention_ref(q, k, v, valid, causal=True, window=w)
    for backend in ("interpret", "xla"):
        o = flash_attention(q, k, v, valid, causal=True, window=w,
                            backend=backend)
        np.testing.assert_allclose(np.asarray(o * lm), np.asarray(ref * lm),
                                   rtol=3e-5, atol=3e-5)
        assert float(jnp.max(jnp.abs(o * (1 - lm)))) == 0.0  # dead rows: 0

        # gradients under a loss that (like real training) never consumes
        # fully-masked rows must match the dense reference
        def loss(f):
            return lambda q, k, v: jnp.sum(jnp.sin(f(q, k, v)) * lm)

        g1 = jax.grad(loss(lambda q, k, v: flash_attention(
            q, k, v, valid, causal=True, window=w, backend=backend)),
            (0, 1, 2))(q, k, v)
        g2 = jax.grad(loss(lambda q, k, v: flash_attention_ref(
            q, k, v, valid, causal=True, window=w)), (0, 1, 2))(q, k, v)
        for name, a, c in zip("qkv", g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=3e-5, atol=3e-5,
                                       err_msg=f"d{name} [{backend}]")


def test_flash_grad_bf16_inputs():
    """bf16 q/k/v: fp32 accumulators inside, bf16 cotangents out."""
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    g1 = jax.grad(lambda q: jnp.sum(flash_attention(
        q, k, v, causal=False, interpret=True).astype(jnp.float32)))(q)
    g2 = jax.grad(lambda q: jnp.sum(flash_attention_ref(
        q, k, v, causal=False).astype(jnp.float32)))(q)
    assert g1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(g1, np.float32),
                               np.asarray(g2, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_sdpa_pads_ragged_lengths():
    """s=200 (not 128-divisible) no longer falls back: the wrapper pads to
    the block multiple, masks the pad rows, and slices — fwd and grads."""
    b, s, h, hkv, d = 2, 200, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    rep = lambda x: jnp.repeat(x.transpose(0, 2, 1, 3), h // hkv, axis=1)
    _grad_case(
        lambda q, k, v: flash_sdpa(q, k, v, causal=False, interpret=True),
        lambda q, k, v: flash_attention_ref(
            q.transpose(0, 2, 1, 3), rep(k), rep(v), causal=False,
        ).transpose(0, 2, 1, 3),
        (q, k, v), tol=3e-5,
    )


def test_flash_sdpa_gqa_without_kv_repeat():
    """The GQA fold is structural: the wrapper and kernels never call
    jnp.repeat — grouped q heads share K/V tiles via the index maps — and
    the grouped result still matches the repeated-K/V dense reference."""
    import inspect

    from repro.kernels import flash_attention as fa_mod
    from repro.kernels import ops as ops_mod

    assert "jnp.repeat(" not in inspect.getsource(ops_mod.flash_sdpa)
    assert "jnp.repeat(" not in inspect.getsource(fa_mod)

    b, s, h, hkv, d = 1, 128, 8, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), jnp.float32)
    rep = lambda x: jnp.repeat(x.transpose(0, 2, 1, 3), h // hkv, axis=1)
    o2 = flash_attention_ref(
        q.transpose(0, 2, 1, 3), rep(k), rep(v), causal=True,
    ).transpose(0, 2, 1, 3)
    for backend in ("interpret", "xla"):
        o1 = flash_sdpa(q, k, v, causal=True, backend=backend)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=3e-5, atol=3e-5)


def test_flash_valid_length_matches_dense_bias():
    """attention-layer valid_len: flash path ≡ dense _mask_bias path."""
    from repro import nn
    from repro.configs.bert_large import smoke
    from repro.models.layers.attention import attention, attention_defs

    cfg = smoke().replace(use_flash_kernel=True)
    p = nn.init_params(attention_defs(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((2, 128, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    # 0 = fully-padded example: both paths clamp to >= 1 key identically
    valid = jnp.asarray([0, 80], jnp.int32)
    y_flash, _ = attention(p, x, pos, cfg, valid_len=valid)
    y_dense, _ = attention(
        p, x, pos, cfg.replace(use_flash_kernel=False), valid_len=valid)
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_flash_sliding_window_layer_matches_dense():
    """SWA configs route through flash (the kernel supports window fwd+bwd);
    layer outputs match the dense positional-bias path."""
    from repro import nn
    from repro.configs.bert_large import smoke
    from repro.models.layers.attention import attention, attention_defs

    cfg = smoke().replace(
        use_flash_kernel=True, causal=True, sliding_window=48)
    p = nn.init_params(attention_defs(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((2, 128, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    y_flash, _ = attention(p, x, pos, cfg)
    y_dense, _ = attention(p, x, pos, cfg.replace(use_flash_kernel=False))
    np.testing.assert_allclose(np.asarray(y_flash), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_flash_fallback_warns_once():
    """use_flash_kernel + unsupported feature ⇒ loud dense fallback."""
    import warnings

    from repro import nn
    from repro.models.layers import attention as attn_mod

    cfg = attn_mod.ModelConfig(
        name="warn-test", family="dense", n_layers=1, d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab_size=64, use_flash_kernel=True,
        logit_softcap=30.0, use_rope=False,
    )
    p = nn.init_params(attn_mod.attention_defs(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((1, 16, 64)), jnp.float32)
    pos = jnp.arange(16)[None]
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        attn_mod.attention(p, x, pos, cfg)
        attn_mod.attention(p, x, pos, cfg)  # second call: deduped
    msgs = [str(w.message) for w in rec if "logit_softcap" in str(w.message)]
    assert len(msgs) == 1, msgs


def test_train_step_flash_equals_dense(tmp_path):
    """End-to-end: one train step of the MLM model with use_flash_kernel=True
    reproduces the dense-attention loss and gradients (CPU: XLA flash)."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data import make_batch
    from repro.models import build_model
    from repro.train import make_train_step

    base = get_config("bert-large").replace(
        name="bert-flash-mini", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, activation_dtype="float32",
    )
    batch = jax.tree.map(
        jnp.asarray, make_batch(base, np.random.default_rng(0), 4, 128)
    )
    key = jax.random.key(0)
    states, metrics = [], []
    for flash in (True, False):
        cfg = base.replace(use_flash_kernel=flash)
        model = build_model(cfg)
        tc = TrainConfig(optimizer="lamb", grad_clip_norm=None)
        init_fn, step_fn = make_train_step(model, tc)
        st, m = jax.jit(step_fn)(init_fn(key), batch)
        states.append(st)
        metrics.append(m)
    assert float(metrics[0]["loss/total"]) == pytest.approx(
        float(metrics[1]["loss/total"]), rel=1e-5)
    assert float(metrics[0]["grad_norm"]) == pytest.approx(
        float(metrics[1]["grad_norm"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(states[0].params),
                    jax.tree.leaves(states[1].params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
