"""Sharding resolution + roofline parsing + (subprocess) production dry-run."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import abstract_mesh
from repro.sharding import default_act_rules, default_param_rules, resolve_spec

MESH_1POD = abstract_mesh((16, 16), ("data", "model"))
MESH_2POD = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_fsdp_and_tp_resolution():
    rules = default_param_rules(multi_pod=False)
    spec = resolve_spec((960, 2560), ("embed", "ff"), rules, MESH_1POD)
    assert spec == P("data", "model")


def test_mqa_kv_head_fallback_replicates():
    rules = default_param_rules()
    spec = resolve_spec((6144, 1, 128), ("embed", "kv_heads", "head_dim"),
                        rules, MESH_1POD)
    assert spec == P("data")  # kv=1 can't shard on model → dropped


def test_odd_head_count_fallback():
    rules = default_param_rules()
    spec = resolve_spec((960, 15, 64), ("embed", "heads", "head_dim"),
                        rules, MESH_1POD)
    assert spec == P("data")


def test_multi_pod_fsdp_uses_both_axes():
    rules = default_param_rules(multi_pod=True)
    spec = resolve_spec((8192, 22528), ("embed", "ff"), rules, MESH_2POD)
    assert spec == P(("pod", "data"), "model")


def test_no_mesh_axis_reuse():
    rules = {"a": ("data",), "b": ("data", "model")}
    spec = resolve_spec((32, 32), ("a", "b"), rules, MESH_1POD)
    flat = []
    for e in spec:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))


def test_cache_seq_takes_leftover_axis():
    """batch=1 long-context decode: cache seq shards over data instead."""
    rules = default_act_rules()
    rules["cache_seq"] = ("pod", "data")
    # batch 128: data used by batch; cache_seq replicates; kv 16 shards
    s1 = resolve_spec((32, 128, 32768, 16, 128),
                      (None, "batch", "cache_seq", "kv_heads", None),
                      rules, MESH_1POD)
    assert s1 == P(None, "data", None, "model")
    # batch 1: batch unshardable, cache_seq takes data; kv=8 < 16 replicates
    s2 = resolve_spec((32, 1, 524288, 8, 128),
                      (None, "batch", "cache_seq", "kv_heads", None),
                      rules, MESH_1POD)
    assert s2 == P(None, None, "data")


# ---------------------------------------------------------------------------
# roofline HLO parsing
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
  %ar = f32[256,64]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[8,8]<=[64]
  %ag = bf16[128,32]{1,0} all-gather(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[16,16]{1,0} reduce-scatter(%y), replica_groups=[4,16]<=[64]
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = f32[128,32]{1,0} all-gather-done(%ag_start)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 64 * 4
    assert out["all-gather"] == 128 * 32 * 2 // 4
    assert out["reduce-scatter"] == 16 * 16 * 4 * 16
    assert out["collective-permute"] == 8 * 4
    assert out["count"] == 4  # -done not double counted


def test_roofline_terms_math():
    from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, analyze

    cost = {"flops": PEAK_FLOPS, "bytes accessed": HBM_BW / 2}
    rf = analyze(cost, "", model_flops_per_device=PEAK_FLOPS / 2)
    assert rf.compute_s == pytest.approx(1.0)
    assert rf.memory_s == pytest.approx(0.5)
    assert rf.dominant == "compute"
    assert rf.useful_fraction == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# production dry-run (subprocess — needs its own XLA_FLAGS before jax import)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_production_dryrun_subprocess(tmp_path):
    """Lower+compile smollm decode_32k on the full 256-chip mesh."""
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-360m", "--shape", "decode_32k",
         "--out", str(out), "--tag", "unit"],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["roofline"]["memory_s"] > 0
    assert rec["cost"]["flops"] > 0


def test_abstract_params_follow_param_dtype():
    from repro.configs import smoke_config
    from repro.models import build_model

    m = build_model(smoke_config("smollm-360m").replace(param_dtype="bfloat16"))
    leaves = jax.tree.leaves(m.abstract_params())
    assert all(l.dtype == "bfloat16" for l in leaves)
