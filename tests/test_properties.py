"""Hypothesis property-based tests on the system's invariants."""
import os
import sys

import pytest

# benchmarks.* (the bench protocol invariants below; tests run PYTHONPATH=src)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
)
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro import core, optim
from repro.core.strategy import trust_ratio
from repro.sharding import resolve_spec

hypothesis.settings.register_profile(
    "repro", deadline=None, max_examples=25, derandomize=True,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("repro")

# NB: allow_subnormal=False everywhere — XLA sets flush-to-zero on the FPU,
# and hypothesis refuses to build subnormal-capable float strategies under FTZ.
finite_arrays = lambda shape: hnp.arrays(
    np.float32, shape,
    elements=st.floats(-10, 10, width=32, allow_nan=False,
                       allow_subnormal=False),
)


@hypothesis.given(
    x=finite_arrays((6, 5)),
    u=finite_arrays((6, 5)),
    c=st.floats(0.1, 100.0),
)
def test_trust_ratio_scales_linearly_with_params(x, u, c):
    """phi=id: ratio(c·x, u) == c·ratio(x, u) whenever norms are nonzero."""
    x, u = jnp.asarray(x), jnp.asarray(u)
    hypothesis.assume(float(jnp.linalg.norm(x)) > 1e-3)
    hypothesis.assume(float(jnp.linalg.norm(u)) > 1e-3)
    r1 = float(trust_ratio(x, u))
    r2 = float(trust_ratio(c * x, u))
    assert abs(r2 - c * r1) <= 1e-3 * abs(c * r1)


@hypothesis.given(
    x=finite_arrays((4, 8)),
    u=finite_arrays((4, 8)),
    lo=st.floats(0.0, 1.0),
    span=st.floats(0.1, 10.0),
)
def test_trust_ratio_respects_phi_bounds(x, u, lo, span):
    x, u = jnp.asarray(x), jnp.asarray(u)
    hypothesis.assume(float(jnp.linalg.norm(u)) > 1e-3)
    hypothesis.assume(float(jnp.linalg.norm(x)) > 1e-3)
    hi = lo + span
    r = float(trust_ratio(x, u, phi_bounds=(lo, hi)))
    un = float(jnp.linalg.norm(u))
    # relative tolerance: the ratio is computed in fp32
    assert (lo / un) * (1 - 1e-5) - 1e-6 <= r <= (hi / un) * (1 + 1e-5) + 1e-6


@hypothesis.given(
    mag=hnp.arrays(np.float32, (5, 4),
                   elements=st.floats(0.0099999997764825821, 10, width=32,
                                      allow_subnormal=False)),
    signs=hnp.arrays(np.bool_, (5, 4)),
    scale=st.floats(0.5, 200.0),
)
def test_lamb_update_invariant_to_gradient_scale(mag, signs, scale):
    """From zero moments LAMB's direction is gradient-scale invariant.

    Gradients are bounded away from zero by construction: eps=0 gives exact
    invariance but makes r = m/sqrt(v) literally 0/0 on zero coordinates
    (the production path uses eps>0)."""
    g = np.where(signs, mag, -mag).astype(np.float32)
    params = {"w": jnp.ones((5, 4))}
    opt = core.lamb(0.01, weight_decay=0.0, eps=0.0)
    u1, _ = opt.update({"w": jnp.asarray(g)}, opt.init(params), params)
    u2, _ = opt.update({"w": jnp.asarray(g * scale)}, opt.init(params), params)
    np.testing.assert_allclose(
        np.asarray(u1["w"]), np.asarray(u2["w"]), rtol=1e-3, atol=1e-5
    )


@hypothesis.given(
    steps=st.integers(2, 500),
    warmup_frac=st.floats(0.01, 0.9),
    base=st.floats(1e-5, 1.0),
)
def test_warmup_poly_schedule_bounded_and_nonnegative(steps, warmup_frac, base):
    warmup = max(int(steps * warmup_frac), 1)
    s = core.warmup_poly_decay(base, steps, warmup)
    ts = jnp.arange(0, steps + 1)
    vals = np.asarray(jax.vmap(s)(ts))
    assert np.all(vals >= -1e-9)
    assert np.all(vals <= base + 1e-9)


@hypothesis.given(
    batch=st.sampled_from([512, 1024, 4096, 16384, 65536]),
)
def test_sqrt_scaling_composition(batch):
    """Scaling 512→B equals 512→2B→B composition (consistency)."""
    a = core.sqrt_scaled_lr(1e-3, 512, batch)
    b = core.sqrt_scaled_lr(core.sqrt_scaled_lr(1e-3, 512, 2048), 2048, batch)
    assert abs(a - b) < 1e-12


# -- convergence-bench protocol invariants (pure recipe / budget math) -------

@hypothesis.given(
    tokens=st.integers(1, 10**9),
    batch=st.integers(1, 65536),
    seq=st.sampled_from([32, 128, 512]),
    k=st.integers(1, 64),
)
def test_fixed_epoch_steps_monotone_and_budget_safe(tokens, batch, seq, k):
    """Fixed-epoch budget: steps never grow with batch, never spend more
    than the token budget (except via the floor of 2), and are deterministic."""
    from benchmarks.common import fixed_epoch_steps

    s = fixed_epoch_steps(tokens, batch, seq)
    assert s == fixed_epoch_steps(tokens, batch, seq)      # deterministic
    assert s >= 2                                          # floor
    assert fixed_epoch_steps(tokens, batch * k, seq) <= s  # monotone in batch
    assert s == 2 or s * batch * seq <= tokens             # budget-safe


@hypothesis.given(
    base=st.floats(1e-5, 1.0),
    base_batch=st.sampled_from([8, 64, 512]),
    k=st.integers(1, 128),
)
def test_recipe_sqrt_lr_exact_on_squares(base, base_batch, k):
    """recipe(): at batch = base·k², the sqrt rule gives exactly k·base_lr,
    and LR is monotone non-decreasing in batch."""
    from benchmarks.protocol import recipe

    r = recipe("lamb", base_batch * k * k, base_batch=base_batch, base_lr=base)
    assert abs(r["lr"] - k * base) <= 1e-9 * k * base
    smaller = recipe("lamb", base_batch, base_batch=base_batch, base_lr=base)
    assert r["lr"] >= smaller["lr"] - 1e-12


@hypothesis.given(
    ratio=st.floats(1e-4, 1.0),
    base_batch=st.sampled_from([8, 64, 512]),
    k=st.integers(1, 4096),
)
def test_linear_epoch_warmup_ratio_bounded_and_monotone(ratio, base_batch, k):
    """Warmup fraction grows linearly with batch and saturates at 1.0 (the
    whole run) — it must stay a valid fraction at any scale."""
    r1 = core.linear_epoch_warmup_ratio(ratio, base_batch, base_batch)
    rk = core.linear_epoch_warmup_ratio(ratio, base_batch, base_batch * k)
    assert 0.0 < r1 <= 1.0 and 0.0 < rk <= 1.0
    assert rk >= r1 - 1e-12                     # monotone in batch
    if ratio * (base_batch * k) / base_batch >= 1.0:
        assert rk == 1.0                        # saturation is exact


@hypothesis.given(
    steps=st.integers(2, 400),
    warmup_frac=st.floats(0.01, 0.99),
    base=st.floats(1e-5, 1.0),
)
def test_warmup_poly_schedule_peaks_at_warmup_end(steps, warmup_frac, base):
    """The §4.1 shape the two-stage re-warm-up relies on: ramp up to the peak
    LR at ``warmup`` (monotone), then decay monotonically toward zero."""
    warmup = max(int(steps * warmup_frac), 1)
    hypothesis.assume(warmup < steps)
    s = core.warmup_poly_decay(base, steps, warmup)
    vals = np.asarray(jax.vmap(s)(jnp.arange(0, steps + 1)))
    peak = vals[warmup]
    assert abs(peak - base) <= 1e-6 * base      # peak is the base LR
    assert np.all(np.diff(vals[: warmup + 1]) >= -1e-9)   # ramp up
    assert np.all(np.diff(vals[warmup:]) <= 1e-9)         # decay down
    assert vals[-1] <= base * 1e-6 + 1e-9                 # ends ~0


@hypothesis.given(
    dims=st.lists(st.sampled_from([1, 3, 5, 15, 16, 48, 64, 960, 1024]),
                  min_size=1, max_size=4),
)
def test_resolve_spec_always_divides(dims):
    """Any resolved PartitionSpec axis product divides its dimension."""
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    rules = {"a": ("data",), "b": ("model",), "c": ("data", "model")}
    names = ["a", "b", "c", None]
    axes = tuple(names[i % 4] for i in range(len(dims)))
    spec = resolve_spec(tuple(dims), axes, rules, mesh)
    used = []
    for dim, entry in zip(dims, tuple(spec) + (None,) * (len(dims) - len(spec))):
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for e in entries:
            assert e not in used, "mesh axis reused"
            used.append(e)
            total *= mesh.shape[e]
        assert dim % total == 0


@hypothesis.given(
    data=hnp.arrays(np.float32, (3, 7),
                    elements=st.floats(-5, 5, width=32, allow_nan=False,
                                       allow_subnormal=False)),
)
def test_apply_updates_inverse(data):
    """apply_updates(p, u) - p == u (fp32 exactness)."""
    p = {"w": jnp.asarray(data)}
    u = {"w": jnp.asarray(data * 0.5)}
    q = optim.apply_updates(p, u)
    np.testing.assert_allclose(np.asarray(q["w"] - p["w"]), np.asarray(u["w"]),
                               rtol=1e-6, atol=1e-6)


@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    layers=st.integers(1, 4),
    per=st.sampled_from([17, 64, 300, 1024]),
)
def test_fused_lamb_kernel_matches_ref_property(seed, layers, per):
    from repro.kernels.lamb_update import lamb_update
    from repro.kernels.ref import lamb_update_ref

    rng = np.random.default_rng(seed)
    shape = (layers, per)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    m = jnp.asarray(rng.standard_normal(shape), jnp.float32) * 0.1
    v = jnp.abs(jnp.asarray(rng.standard_normal(shape), jnp.float32)) * 0.01
    kw = dict(lr=0.01, weight_decay=0.01)
    x1, m1, v1 = lamb_update(x, g, m, v, jnp.asarray(2), layer_axis=0,
                             interpret=True, **kw)
    x2, m2, v2 = lamb_update_ref(x, g, m, v, step=2, layer_axis=0, **kw)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=3e-5, atol=3e-6)
