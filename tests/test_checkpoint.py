"""Checkpoint round-trips, crash consistency, and the async checkpointer.

Three layers of coverage:

* hypothesis property tests: arbitrary nested pytrees of fp32/bf16/int32
  arrays and scalar leaves survive ``save_checkpoint`` →
  ``restore_checkpoint`` *bitwise* (bf16 goes through the uint16-view npy
  encoding — a plain np.save would degrade it to raw void records);
* crash consistency: failures injected into the save path (raising
  ``np.save``/``os.rename``, a hard mid-save abort via the
  ``after_leaf_write`` hook) must never advance LATEST past the last
  complete checkpoint, and the next save garbage-collects the debris — the
  in-process twin of the SIGKILL scenarios in tests/sharded_harness.py;
* AsyncCheckpointer: saves overlap a slow disk (save returns while the
  write is still in flight), at most one write is in flight, background
  failures surface on ``wait``, and ``checkpoint`` telemetry events carry
  the snapshot/blocked/write timings.
"""
import itertools
import json
import os
import shutil
import tempfile
import time

import pytest

try:  # property tests gate on hypothesis; everything else must still run
    import hypothesis
    import hypothesis.strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import (  # noqa: E402
    AsyncCheckpointer,
    checkpoint_step,
    gc_tmp_dirs,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint import io as ckpt_io  # noqa: E402
from repro.telemetry import EventLog  # noqa: E402

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS,
    reason="hypothesis not installed (see requirements-dev.txt)",
)


@pytest.fixture(autouse=True)
def _reset_fault_hook():
    yield
    ckpt_io.after_leaf_write = None


# ---------------------------------------------------------------------------
# property tests: round-trip over structures, dtypes, scalar leaves
# ---------------------------------------------------------------------------

def _leaf_arrays(rng: np.random.Generator, spec):
    dtype, shape = spec
    if dtype == "int32":
        return rng.integers(-1000, 1000, size=shape).astype(np.int32)
    x = rng.standard_normal(shape).astype(np.float32)
    if dtype == "bfloat16":
        return np.asarray(jnp.asarray(x, jnp.bfloat16))
    return x


_DTYPES = ["float32", "bfloat16", "int32"]
_SHAPES = [(), (3,), (2, 4), (1, 2, 3)]  # incl. 0-d scalars

if HAS_HYPOTHESIS:
    _leaf_specs = st.tuples(st.sampled_from(_DTYPES), st.sampled_from(_SHAPES))
    _trees = st.recursive(
        _leaf_specs,
        lambda kids: st.dictionaries(
            st.sampled_from(["w", "b", "mu", "nu", "blocks", "s/1"]), kids,
            min_size=1, max_size=3,
        ),
        max_leaves=8,
    )
    SETTINGS = hypothesis.settings(
        deadline=None, max_examples=20, derandomize=True,
        suppress_health_check=[hypothesis.HealthCheck.too_slow],
    )


def _assert_bitwise_equal(a, b):
    fa = jax.tree_util.tree_leaves_with_path(a)
    fb = jax.tree_util.tree_leaves_with_path(b)
    assert [k for k, _ in fa] == [k for k, _ in fb]
    for (_, x), (_, y) in zip(fa, fb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def _roundtrip_case(tree_spec, step):
    rng = np.random.default_rng(0)
    tree = jax.tree.map(
        lambda s: _leaf_arrays(rng, s), tree_spec,
        is_leaf=lambda n: isinstance(n, tuple) and len(n) == 2
        and isinstance(n[0], str),
    )
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, step, tree)
        assert checkpoint_step(path) == step
        assert latest_checkpoint(d) == path
        restored = restore_checkpoint(path, tree)
    _assert_bitwise_equal(tree, restored)


if HAS_HYPOTHESIS:

    @SETTINGS
    @hypothesis.given(tree_spec=_trees, step=st.integers(0, 10**7))
    def test_roundtrip_preserves_bits(tree_spec, step):
        _roundtrip_case(tree_spec, step)

else:

    @needs_hypothesis
    def test_roundtrip_preserves_bits():
        raise AssertionError("unreachable: skipif gates this test")


@pytest.mark.parametrize(
    "dtype,shape", list(itertools.product(_DTYPES, _SHAPES)),
    ids=lambda v: str(v).replace(" ", ""),
)
def test_roundtrip_dtype_shape_grid(dtype, shape):
    """Deterministic twin of the hypothesis sweep: every dtype × shape
    combination (incl. bf16 scalars, whose npy encoding goes through the
    uint16 view) round-trips bitwise, nested one level deep."""
    _roundtrip_case({"outer": {"leaf": (dtype, shape)}, "top": (dtype, ())}, 7)


def test_jax_arrays_and_scalar_step_roundtrip(tmp_path):
    tree = {"w": jnp.ones((4, 2), jnp.bfloat16) * 1.5,
            "step": jnp.asarray(7, jnp.int32),
            "nested": {"v": jnp.arange(6, dtype=jnp.float32)}}
    path = save_checkpoint(str(tmp_path), 7, tree)
    restored = restore_checkpoint(path, jax.eval_shape(lambda: tree))
    _assert_bitwise_equal(jax.tree.map(np.asarray, tree), restored)


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": np.ones((4, 4), np.float32)})
    bad = {"w": jax.ShapeDtypeStruct((2, 2), jnp.float32)}
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(latest_checkpoint(str(tmp_path)), bad)


def test_restore_dtype_mismatch_raises_unless_cast(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": np.ones((3,), np.float32)})
    bad = {"w": jax.ShapeDtypeStruct((3,), jnp.bfloat16)}
    path = latest_checkpoint(str(tmp_path))
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(path, bad)
    restored = restore_checkpoint(path, bad, cast=True)
    assert restored["w"].dtype == jnp.bfloat16


def test_restore_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": np.ones((3,), np.float32)})
    bad = {"w": np.ones((3,), np.float32), "extra": np.zeros((2,), np.float32)}
    with pytest.raises(KeyError, match="extra"):
        restore_checkpoint(latest_checkpoint(str(tmp_path)), bad)


# ---------------------------------------------------------------------------
# latest_checkpoint / checkpoint_step edge cases
# ---------------------------------------------------------------------------

def test_latest_checkpoint_empty_and_missing_dir(tmp_path):
    assert latest_checkpoint(str(tmp_path)) is None           # empty dir
    assert latest_checkpoint(str(tmp_path / "nope")) is None  # missing dir


def test_latest_checkpoint_orders_steps(tmp_path):
    tree = {"w": np.ones((2,), np.float32)}
    for step in (1, 2, 10):  # zero-padded names keep lexicographic == numeric
        save_checkpoint(str(tmp_path), step, tree)
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 10


def test_stale_pointer_falls_back_to_newest_complete(tmp_path):
    tree = {"w": np.ones((2,), np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    p2 = save_checkpoint(str(tmp_path), 2, tree)
    shutil.rmtree(p2)  # LATEST now names a vanished checkpoint
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 1


def test_pointer_to_partial_checkpoint_is_ignored(tmp_path):
    tree = {"w": np.ones((2,), np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    # a "checkpoint" dir with no manifest = a torn write that never happened
    os.makedirs(tmp_path / "step_00000009")
    (tmp_path / "LATEST").write_text("step_00000009")
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 1


def test_stale_pointer_with_no_complete_checkpoint(tmp_path):
    (tmp_path / "LATEST").write_text("step_00000004")
    assert latest_checkpoint(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# crash consistency: injected failures in the save path
# ---------------------------------------------------------------------------

def test_np_save_failure_keeps_previous_checkpoint(tmp_path, monkeypatch):
    tree = {"w": np.ones((2,), np.float32), "b": np.zeros((2,), np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)

    real_save = np.save
    calls = {"n": 0}

    def flaky_save(path, arr, **kw):
        if calls["n"] >= 1:
            raise OSError("disk full")
        calls["n"] += 1
        return real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", flaky_save)
    with pytest.raises(OSError, match="disk full"):
        save_checkpoint(str(tmp_path), 2, tree)
    monkeypatch.undo()

    # the failed save cleaned its tmp dir and never touched LATEST
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 1
    assert not any(n.startswith(".tmp_ckpt_") for n in os.listdir(tmp_path))


def test_rename_failure_keeps_previous_checkpoint(tmp_path, monkeypatch):
    tree = {"w": np.ones((2,), np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)

    def bad_rename(src, dst):
        raise OSError("rename EIO")

    monkeypatch.setattr(ckpt_io.os, "rename", bad_rename)
    with pytest.raises(OSError, match="rename"):
        save_checkpoint(str(tmp_path), 2, tree)
    monkeypatch.undo()
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 1
    assert not any(n.startswith(".tmp_ckpt_") for n in os.listdir(tmp_path))


class _HardCrash(BaseException):
    """Not an Exception: skips the save's cleanup path, like a SIGKILL."""


def test_mid_save_hard_crash_then_gc_on_next_save(tmp_path):
    tree = {"w": np.ones((2,), np.float32), "b": np.zeros((3,), np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)

    def die_after_first_leaf(i, _tmp):
        if i == 0:
            raise _HardCrash

    ckpt_io.after_leaf_write = die_after_first_leaf
    with pytest.raises(_HardCrash):
        save_checkpoint(str(tmp_path), 2, tree)
    ckpt_io.after_leaf_write = None

    # the aborted write left debris, but LATEST still names step 1 and the
    # partial dir is never eligible as a checkpoint
    assert any(n.startswith(".tmp_ckpt_") for n in os.listdir(tmp_path))
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 1

    # the next save garbage-collects the stray tmp dir and publishes
    save_checkpoint(str(tmp_path), 3, tree)
    strays = [n for n in os.listdir(tmp_path) if n.startswith(".tmp_ckpt_")]
    assert strays == []
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 3


def test_gc_tmp_dirs_removes_manual_debris(tmp_path):
    os.makedirs(tmp_path / ".tmp_ckpt_dead")
    (tmp_path / ".tmp_latest_dead").write_text("x")
    (tmp_path / "keep.txt").write_text("x")
    removed = gc_tmp_dirs(str(tmp_path))
    assert sorted(removed) == [".tmp_ckpt_dead", ".tmp_latest_dead"]
    assert (tmp_path / "keep.txt").exists()


def test_latest_pointer_written_atomically(tmp_path, monkeypatch):
    """LATEST updates go through tmp-file + rename: the pointer file itself
    is never open for writing in place."""
    tree = {"w": np.ones((2,), np.float32)}
    renames = []
    real_rename = os.rename

    def spy_rename(src, dst):
        renames.append((os.path.basename(src), os.path.basename(dst)))
        return real_rename(src, dst)

    monkeypatch.setattr(ckpt_io.os, "rename", spy_rename)
    save_checkpoint(str(tmp_path), 5, tree)
    assert any(src.startswith(".tmp_latest_") and dst == "LATEST"
               for src, dst in renames), renames


# ---------------------------------------------------------------------------
# AsyncCheckpointer
# ---------------------------------------------------------------------------

def _tiny_state():
    return {"params": {"w": jnp.ones((8, 4)) * 2.0},
            "mu": {"w": jnp.zeros((8, 4))},
            "step": jnp.asarray(3, jnp.int32)}


def test_async_save_roundtrip_and_latest_persisted(tmp_path):
    state = _tiny_state()
    with AsyncCheckpointer(str(tmp_path)) as ck:
        assert ck.latest_persisted_step() is None
        ck.save(3, state)
        path = ck.wait()
        assert ck.latest_persisted_step() == 3
    restored = restore_checkpoint(path, jax.eval_shape(lambda: state))
    _assert_bitwise_equal(jax.tree.map(np.asarray, state), restored)


def test_async_write_overlaps_caller(tmp_path, monkeypatch):
    """save() must return while the (artificially slow) disk write is still
    in flight; the checkpoint becomes visible only after wait()."""
    real_save = np.save

    def slow_save(path, arr, **kw):
        time.sleep(0.15)
        return real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", slow_save)
    state = _tiny_state()  # 3 leaves -> >= 0.45s of "disk" time
    with AsyncCheckpointer(str(tmp_path)) as ck:
        t0 = time.perf_counter()
        ck.save(3, state)
        returned_after = time.perf_counter() - t0
        assert returned_after < 0.4, returned_after
        assert ck.latest_persisted_step() is None  # not durable yet
        ck.wait()
        assert ck.latest_persisted_step() == 3
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 3


def test_async_at_most_one_write_in_flight(tmp_path, monkeypatch):
    """A second save waits out the first write (recorded as blocked_s), so
    writes never queue unboundedly and publish in order."""
    real_save = np.save

    def slow_save(path, arr, **kw):
        time.sleep(0.05)
        return real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", slow_save)
    log = EventLog.memory()
    state = _tiny_state()
    with AsyncCheckpointer(str(tmp_path), telemetry=log) as ck:
        ck.save(1, state)
        ck.save(2, state)  # must block on save(1)'s write
        ck.wait()
    evs = [e for e in log.events if e["event"] == "checkpoint"]
    assert [e["step"] for e in evs] == [1, 2]
    assert all(e["mode"] == "async" for e in evs)
    for key in ("snapshot_s", "blocked_s", "write_s"):
        assert all(key in e for e in evs), evs
    assert evs[1]["blocked_s"] > 0.0, evs
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == 2


def test_async_background_failure_surfaces_on_wait(tmp_path, monkeypatch):
    def bad_save(path, arr, **kw):
        raise OSError("disk gone")

    monkeypatch.setattr(np, "save", bad_save)
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(1, _tiny_state())
    with pytest.raises(OSError, match="disk gone"):
        ck.wait()
    monkeypatch.undo()
    assert ck.latest_persisted_step() is None
    assert latest_checkpoint(str(tmp_path)) is None
    ck.close()


def test_async_resumes_latest_persisted_from_disk(tmp_path):
    save_checkpoint(str(tmp_path), 4, {"w": np.ones((2,), np.float32)})
    ck = AsyncCheckpointer(str(tmp_path))
    assert ck.latest_persisted_step() == 4
    ck.close()


# ---------------------------------------------------------------------------
# Trainer integration: full-state saves + resume
# ---------------------------------------------------------------------------

def _tiny_trainer(ckpt_dir=None, **kw):
    from repro.configs.base import TrainConfig
    from repro.models import build_model
    from repro.train import Trainer

    from tests.conftest import tiny_dense

    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    return Trainer(build_model(tiny_dense()), tc, checkpoint_dir=ckpt_dir,
                   log_every=1, log_fn=lambda s: None, **kw)


def _data(seed=0):
    from repro.data import DataPipeline

    from tests.conftest import tiny_dense

    return DataPipeline(tiny_dense(), 8, 16, seed=seed)


def test_trainer_saves_full_train_state(tmp_path):
    tr = _tiny_trainer(str(tmp_path), checkpoint_every=2)
    tr.fit(_data(), 2)
    path = latest_checkpoint(str(tmp_path))
    manifest = json.loads(
        (open(os.path.join(path, "manifest.json"))).read())
    paths = [e["path"] for e in manifest["leaves"]]
    assert any(p.startswith("params/") for p in paths)
    assert any(p.startswith("opt_state/") for p in paths), (
        "optimizer moments must survive a restart")
    assert "step" in paths, "the step counter must survive a restart"


@pytest.mark.parametrize("use_async", [False, True])
def test_trainer_resume_continues_bit_exact(tmp_path, use_async):
    ref = _tiny_trainer()
    ref.fit(_data(), 5)

    tr1 = _tiny_trainer(str(tmp_path), checkpoint_every=3,
                        async_checkpoint=use_async)
    tr1.fit(_data(), 3)

    tr2 = _tiny_trainer(str(tmp_path), checkpoint_every=3,
                        async_checkpoint=use_async, resume=True)
    tr2.fit(_data(), 5)

    def rows(tr, after):
        return [{k: v for k, v in r.items() if k != "wall_s"}
                for r in tr.history if r["step"] > after]

    assert rows(tr2, 3) == rows(ref, 3)
    assert tr2.examples_seen == ref.examples_seen
    assert int(tr2.state.step) == 5


def test_trainer_resume_with_no_checkpoint_starts_fresh(tmp_path):
    tr = _tiny_trainer(str(tmp_path), checkpoint_every=0, resume=True)
    tr.fit(_data(), 2)
    assert int(tr.state.step) == 2


def test_trainer_resume_past_target_runs_nothing(tmp_path):
    tr1 = _tiny_trainer(str(tmp_path), checkpoint_every=2)
    tr1.fit(_data(), 4)
    tr2 = _tiny_trainer(str(tmp_path), checkpoint_every=2, resume=True)
    tr2.fit(_data(), 3)  # target already passed by the checkpoint
    assert tr2.history == []
    assert int(tr2.state.step) == 4
