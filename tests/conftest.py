import os

# Tests run on the single real CPU device (the dry-run subprocess sets its own
# XLA_FLAGS).  Keep x64 off and make test ordering deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (production dry-run subprocess)"
    )


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.key(0)


def tiny_dense(**kw):
    from repro.configs.base import ModelConfig

    base = dict(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base)
