"""Schedule library vs the paper's Tables 4-5 (exact recipe values)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core


BERT_TABLE4 = {  # batch → (lr, warmup_ratio)  [Table 4]
    512: (5 / (2**3.0 * 1e3), 1 / 320),
    1024: (5 / (2**2.5 * 1e3), 1 / 160),
    2048: (5 / (2**2.0 * 1e3), 1 / 80),
    4096: (5 / (2**1.5 * 1e3), 1 / 40),
    8192: (5 / (2**1.0 * 1e3), 1 / 20),
    16384: (5 / (2**0.5 * 1e3), 1 / 10),
    32768: (5 / (2**0.0 * 1e3), 1 / 5),
}

RESNET_TABLE5 = {  # batch → lr  [Table 5, base 4/(2^3*100) @ 512]
    512: 4 / (2**3.0 * 100),
    32768: 4 / (2**0.0 * 100),
}


@pytest.mark.parametrize("batch", sorted(BERT_TABLE4))
def test_table4_sqrt_scaling_and_warmup(batch):
    lr, ratio = BERT_TABLE4[batch]
    assert core.sqrt_scaled_lr(5 / (2**3 * 1e3), 512, batch) == pytest.approx(lr)
    assert core.linear_epoch_warmup_ratio(1 / 320, 512, batch) == pytest.approx(ratio)


def test_table4_32k_warmup_steps():
    """Paper: batch 32K → 15625 iterations, 0.2·15625 = 3125 warmup steps."""
    _, info = core.untuned_lamb_schedule(32768, 15625)
    assert info["warmup_steps"] == 3125
    assert info["learning_rate"] == pytest.approx(5e-3)


@pytest.mark.parametrize("batch", sorted(RESNET_TABLE5))
def test_table5_resnet_lr(batch):
    assert core.sqrt_scaled_lr(4 / (2**3 * 100), 512, batch) == pytest.approx(
        RESNET_TABLE5[batch]
    )


def test_poly_decay_endpoints():
    s = core.polynomial_decay(1.0, 100)
    assert float(s(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(50))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.0)


def test_warmup_poly_profile():
    s = core.warmup_poly_decay(1.0, 100, 10)
    vals = [float(s(jnp.asarray(t))) for t in range(0, 101, 5)]
    assert vals[0] == 0.0
    assert max(vals) == pytest.approx(1.0, abs=1e-6)
    # monotone up then monotone down
    peak = int(np.argmax(vals))
    assert all(a <= b + 1e-9 for a, b in zip(vals[:peak], vals[1:peak + 1]))
    assert all(a >= b - 1e-9 for a, b in zip(vals[peak:-1], vals[peak + 1:]))


def test_piecewise_stage_rewarmup():
    """Stage 2 restarts from ~0 (re-warm-up), not from stage 1's decayed LR."""
    s1 = core.warmup_poly_decay(1.0, 50, 5)
    s2 = core.warmup_poly_decay(0.7, 50, 10)
    s = core.piecewise_stage_schedule([s1, s2], [50, 50])
    end_stage1 = float(s(jnp.asarray(49)))
    start_stage2 = float(s(jnp.asarray(50)))
    assert start_stage2 < 0.1  # re-warmed from zero
    assert float(s(jnp.asarray(60))) == pytest.approx(0.7, rel=1e-5)


def test_goyal_schedule():
    s = core.goyal_step_schedule(1.0, steps_per_epoch=10)
    assert float(s(jnp.asarray(25))) == pytest.approx(0.5)     # mid warmup
    assert float(s(jnp.asarray(100))) == pytest.approx(1.0)    # after warmup
    assert float(s(jnp.asarray(350))) == pytest.approx(0.1)    # after 30 epochs
    assert float(s(jnp.asarray(650))) == pytest.approx(0.01)   # after 60
    assert float(s(jnp.asarray(850))) == pytest.approx(0.001)  # after 80


def test_adam_correction_equivalent_lr_looks_like_warmup():
    """App. E: the implicit factor starts small and approaches 1 — a warmup."""
    ts = jnp.arange(0, 5000, 10)
    f = np.asarray(core.adam_correction_equivalent_lr(ts))
    assert f[0] < 0.5          # strongly damped early steps
    assert abs(f[-1] - 1.0) < 0.05  # approaches the nominal LR
    assert f[-1] > f[0]


def test_mixed_batch_plan_matches_paper():
    """§4.1: 64K/32K mixed-batch, 8599 total iterations, stage-2 re-warmup."""
    plan = core.bert_mixed_batch_plan()
    assert plan[0].batch_size == 65536 and plan[0].seq_len == 128
    assert plan[1].batch_size == 32768 and plan[1].seq_len == 512
    assert plan[0].steps + plan[1].steps == 8599
    # sqrt-scaled LRs from the 512-batch base
    assert plan[0].learning_rate == pytest.approx(
        core.sqrt_scaled_lr(5 / (2**3 * 1e3), 512, 65536)
    )
    assert plan[1].warmup_steps > 0  # re-warm-up exists
