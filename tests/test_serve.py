"""Continuous-batching serving subsystem: KV pool, scheduler, engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    Engine,
    FCFSScheduler,
    KVPool,
    Request,
    RequestStatus,
    ServeRequest,
    assign_arrivals,
    poisson_arrivals,
    request_tokens,
    sample_tokens,
)
from repro.serve.continuous import make_pool_decode_step, make_pool_prefill


@pytest.fixture(scope="module")
def served():
    model = build_model(tiny_dense())
    params = model.init(jax.random.key(0))
    return model, params


def _prompts(n, s=10, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, size=s).astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------------------
# KV pool
# ---------------------------------------------------------------------------

def test_kv_pool_slot_reuse_and_isolation(served):
    """Evict → insert reuses the freed slot; the other slot's decode stream
    is bit-identical whatever its neighbour holds."""
    model, params = served
    max_len = 32
    prefill = jax.jit(make_pool_prefill(model, max_len))
    step = jax.jit(make_pool_decode_step(model, greedy=True))
    p0, p1, p2 = _prompts(3, s=8)

    def decode_token(pool, tokens):
        nxt, _, _ = step(
            params, pool.cache, jnp.asarray(tokens),
            jnp.asarray(pool.lengths), jnp.asarray(pool.active_mask),
            jnp.zeros(pool.n_slots, jnp.float32),
            jnp.zeros(pool.n_slots, jnp.int32),
            jax.random.key(0), np.int32(0),
        )
        return np.asarray(nxt)

    def fill(pool, prompt, slot):
        last, cache1 = prefill(params, jnp.asarray(prompt[None]))
        pool.insert(cache1, slot, len(prompt))
        return int(jnp.argmax(last, -1)[0])

    pool = KVPool(model, 2, max_len)
    s0, s1 = pool.acquire(), pool.acquire()
    assert (s0, s1) == (0, 1) and pool.n_free == 0
    t0 = fill(pool, p0, s0)
    t1 = fill(pool, p1, s1)
    before = decode_token(pool, [t0, t1])

    # evict slot 0 → it is the slot handed out next (reuse), slot 1 untouched
    pool.evict(s0)
    assert pool.acquire() == s0
    t2 = fill(pool, p2, s0)
    after = decode_token(pool, [t2, t1])
    assert after[1] == before[1]  # isolation: neighbour swap is invisible
    assert pool.lengths[s0] == len(p2)

    # reference: slot-1 request decoded alone in a fresh pool (slot 0 empty)
    solo = KVPool(model, 2, max_len)
    fill(solo, p1, 1)
    ref = decode_token(solo, [0, t1])
    assert ref[1] == before[1]


def test_kv_pool_rejects_oversized_prompt(served):
    model, _ = served
    pool = KVPool(model, 1, 8)
    with pytest.raises(ValueError):
        pool.insert(model.make_cache(1, 8), slot=0, length=9)


# ---------------------------------------------------------------------------
# continuous engine
# ---------------------------------------------------------------------------

def test_continuous_matches_static_greedy(served):
    """Token-for-token greedy equivalence on a shared request set, with more
    requests than slots so the pool has to swap mid-decode."""
    model, params = served
    prompts = _prompts(5)
    new = [6, 3, 8, 5, 7]
    eng = Engine(model, params, max_len=32)
    ref = eng.generate_batch(
        [Request(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    ce = ContinuousEngine(model, params, n_slots=2, max_len=32)
    out = ce.generate(
        [ServeRequest(p, max_new_tokens=m) for p, m in zip(prompts, new)])
    for r, s in zip(out, ref):
        np.testing.assert_array_equal(
            np.asarray(r.out_tokens), np.asarray(s.out_tokens))
    assert ce.pool.n_free == 2  # everything evicted at drain


def test_per_request_termination_mixed_max_new(served):
    model, params = served
    ce = ContinuousEngine(model, params, n_slots=3, max_len=32)
    new = [1, 4, 9, 2, 6]
    out = ce.generate(
        [ServeRequest(p, max_new_tokens=m)
         for p, m in zip(_prompts(5, seed=3), new)])
    assert [len(r.out_tokens) for r in out] == new
    assert all(np.isfinite(r.finish_s) for r in out)


def test_eos_termination(served):
    model, params = served
    prompts = _prompts(1, seed=5)
    ce = ContinuousEngine(model, params, n_slots=1, max_len=32)
    ref = ce.generate([ServeRequest(prompts[0], max_new_tokens=8)])[0]
    eos = ref.out_tokens[3]
    assert eos not in ref.out_tokens[:3]  # pick a token that first fires at 3
    ce2 = ContinuousEngine(model, params, n_slots=1, max_len=32)
    out = ce2.generate(
        [ServeRequest(prompts[0], max_new_tokens=8, eos_token=eos)])[0]
    assert out.out_tokens == ref.out_tokens[:4]  # stops at (and keeps) EOS


def test_streaming_callback_matches_output(served):
    model, params = served
    ce = ContinuousEngine(model, params, n_slots=2, max_len=32)
    seen = {}
    out = ce.generate(
        [ServeRequest(p, max_new_tokens=5) for p in _prompts(3, seed=9)],
        on_token=lambda r, t: seen.setdefault(r.rid, []).append(t),
    )
    for r in out:
        assert seen[r.rid] == r.out_tokens


# ---------------------------------------------------------------------------
# static engine regression: per-request temperature
# ---------------------------------------------------------------------------

def test_engine_per_request_temperature(served):
    """A greedy (temp=0) row must decode greedily even when another request
    in the batch samples at high temperature (regression: the whole batch
    used requests[0].temperature)."""
    model, params = served
    prompts = _prompts(2, seed=11)
    eng = Engine(model, params, max_len=32)
    ref = eng.generate_batch(
        [Request(p.copy(), max_new_tokens=8) for p in prompts])
    eng2 = Engine(model, params, max_len=32)
    mixed = eng2.generate_batch([
        Request(prompts[0].copy(), max_new_tokens=8, temperature=1.5),
        Request(prompts[1].copy(), max_new_tokens=8, temperature=0.0),
    ])
    np.testing.assert_array_equal(mixed[1].out_tokens, ref[1].out_tokens)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sample_tokens_vectorized():
    rng = jax.random.key(0)
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 32)), jnp.float32)
    greedy = np.asarray(jnp.argmax(logits, -1))

    out = np.asarray(sample_tokens(rng, logits, jnp.zeros(3)))
    np.testing.assert_array_equal(out, greedy)  # temp 0 → argmax

    # top_k=1 is argmax regardless of temperature
    out = np.asarray(sample_tokens(
        rng, logits, jnp.full(3, 5.0), jnp.ones(3, jnp.int32)))
    np.testing.assert_array_equal(out, greedy)

    # mixed rows: greedy rows stay greedy, sampled rows stay in-vocab
    out = np.asarray(sample_tokens(
        rng, logits, jnp.asarray([0.0, 2.0, 0.0])))
    assert out[0] == greedy[0] and out[2] == greedy[2]
    assert 0 <= out[1] < 32


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_fcfs_order_and_prefill_budget():
    sched = FCFSScheduler(max_prefills_per_step=2)
    reqs = [ServeRequest(np.zeros(4, np.int32), arrival_s=t)
            for t in (0.3, 0.1, 0.2)]
    for r in reqs:
        sched.submit(r)
    admitted, dropped = sched.admit(now=1.0, free_slots=3)
    assert not dropped
    assert [r.arrival_s for r in admitted] == [0.1, 0.2]  # FCFS, budget 2
    admitted, _ = sched.admit(now=1.0, free_slots=3)
    assert [r.arrival_s for r in admitted] == [0.3]
    assert not sched.has_pending()


def test_scheduler_deadline_drop():
    sched = FCFSScheduler()
    kept = sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=0.0))
    late = sched.submit(
        ServeRequest(np.zeros(4, np.int32), arrival_s=0.0, deadline_s=0.5))
    admitted, dropped = sched.admit(now=1.0, free_slots=2)
    assert admitted == [kept] and dropped == [late] and late.dropped


def test_queue_depth_counts_only_arrived_requests():
    """queue_depth(now) must track the sorted queue incrementally — counting
    only requests with arrival_s <= now — through out-of-order submits and
    interleaved admissions."""
    sched = FCFSScheduler(max_prefills_per_step=1)
    for t in (0.3, 0.1, 0.2, 5.0):
        sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=t))
    assert sched.queue_depth(0.0) == 0
    assert sched.queue_depth(0.15) == 1
    assert sched.queue_depth(0.3) == 3      # boundary: arrival_s == now counts
    assert sched.queue_depth(1.0) == 3      # the t=5.0 request hasn't arrived
    admitted, _ = sched.admit(now=1.0, free_slots=4)
    assert [r.arrival_s for r in admitted] == [0.1]
    assert sched.queue_depth(1.0) == 2      # keys popped alongside the queue
    sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=0.05))
    assert sched.queue_depth(1.0) == 3      # late submit lands mid-queue
    admitted, _ = sched.admit(now=1.0, free_slots=4)
    assert [r.arrival_s for r in admitted] == [0.05]  # still FCFS by arrival
    assert sched.queue_depth(10.0) == 3


def test_arrival_processes():
    t = poisson_arrivals(16, rate=10.0, seed=0)
    assert len(t) == 16 and t[0] == 0.0 and np.all(np.diff(t) >= 0)
    assert np.all(poisson_arrivals(4, rate=0.0) == 0.0)
    reqs = assign_arrivals(
        [ServeRequest(np.zeros(2, np.int32)) for _ in range(3)],
        np.array([0.0, 0.5, 1.0]))
    assert [r.arrival_s for r in reqs] == [0.0, 0.5, 1.0]


def test_sweep_expires_with_zero_free_slots():
    """The lazy-deadline regression: expirations must leave the queue on
    every admit() call even when the pool is saturated (free_slots=0), so
    queue depth stays honest under load."""
    sched = FCFSScheduler()
    expired = sched.submit(
        ServeRequest(np.zeros(4, np.int32), arrival_s=0.0, deadline_s=0.5))
    kept = sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=0.0))
    admitted, removed = sched.admit(now=1.0, free_slots=0)
    assert admitted == [] and removed == [expired]
    assert expired.status is RequestStatus.SHED
    assert expired.shed_reason == "deadline"
    assert sched.queue_depth(1.0) == 1 and sched.has_pending()
    admitted, _ = sched.admit(now=1.0, free_slots=1)
    assert admitted == [kept]


def test_sweep_times_out_queued_requests():
    """A request whose total latency budget expires while still queued is
    TIMED_OUT (not shed) — the two counters stay disjoint."""
    sched = FCFSScheduler()
    late = sched.submit(
        ServeRequest(np.zeros(4, np.int32), arrival_s=0.0, timeout_s=0.4))
    _, removed = sched.admit(now=1.0, free_slots=0)
    assert removed == [late]
    assert late.status is RequestStatus.TIMED_OUT and late.dropped


def test_bounded_queue_sheds_newest_keeps_fcfs():
    """Overload shedding evicts the *newest* arrivals beyond the bound with
    a typed queue_full result; survivors are admitted in FCFS order."""
    sched = FCFSScheduler(max_prefills_per_step=4, max_queue=2)
    reqs = [sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=t))
            for t in (0.0, 0.1, 0.2, 0.3)]
    admitted, removed = sched.admit(now=1.0, free_slots=0)
    assert admitted == []
    assert sorted(r.arrival_s for r in removed) == [0.2, 0.3]
    assert all(r.status is RequestStatus.SHED
               and r.shed_reason == "queue_full" for r in removed)
    admitted, _ = sched.admit(now=1.0, free_slots=4)
    assert [r.arrival_s for r in admitted] == [0.0, 0.1]  # FCFS preserved
    assert all(r is reqs[i] for i, r in enumerate(admitted))


def test_bounded_queue_token_budget():
    """max_queue_tokens bounds the backlog by estimated prompt+generation
    tokens, not request count."""
    sched = FCFSScheduler(max_queue_tokens=24)
    a = sched.submit(ServeRequest(np.zeros(8, np.int32), max_new_tokens=4))
    b = sched.submit(ServeRequest(np.zeros(8, np.int32), max_new_tokens=4))
    c = sched.submit(ServeRequest(np.zeros(8, np.int32), max_new_tokens=4))
    assert request_tokens(a) == 12
    _, removed = sched.admit(now=0.0, free_slots=0)
    assert removed == [c]  # 12 + 12 fit, the third overflows
    assert b.status is RequestStatus.PENDING


def test_scheduler_drain_sheds_everything():
    """drain() sheds arrived *and* future requests with reason drain."""
    sched = FCFSScheduler()
    reqs = [sched.submit(ServeRequest(np.zeros(4, np.int32), arrival_s=t))
            for t in (0.0, 5.0)]
    removed = sched.drain(now=1.0)
    assert removed == reqs and not sched.has_pending()
    assert all(r.status is RequestStatus.SHED and r.shed_reason == "drain"
               for r in reqs)


def test_engine_enforces_pool_capacity(served):
    model, params = served
    ce = ContinuousEngine(model, params, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        ce.submit(ServeRequest(np.zeros(10, np.int32), max_new_tokens=10))


def test_engine_rejects_bad_sampling_params(served):
    """submit() validates sampling params up front — a NaN temperature or a
    negative top_k must fail at submission, not poison a decode step."""
    model, params = served
    ce = ContinuousEngine(model, params, n_slots=2, max_len=16)
    for bad in (float("nan"), float("inf"), -0.5):
        with pytest.raises(ValueError, match="temperature"):
            ce.submit(ServeRequest(np.zeros(4, np.int32), temperature=bad))
    with pytest.raises(ValueError, match="top_k"):
        ce.submit(ServeRequest(np.zeros(4, np.int32), top_k=-1))
    # the boundary values stay legal: greedy and disabled-top_k
    ce.submit(ServeRequest(np.zeros(4, np.int32), max_new_tokens=4,
                           temperature=0.0, top_k=0))
