"""Multi-device harness for the sharded train path (run as a subprocess).

Forces 8 virtual CPU devices via XLA_FLAGS *before* importing jax — the flag
only takes effect at backend init, which is why tests/test_sharded_train.py
runs this file as a subprocess (the pytest process already initialized jax
on the single real CPU device; same pattern as the production dry-run).

    PYTHONPATH=src python tests/sharded_harness.py [scenario ...]

Prints one JSON object on the last stdout line.  Scenarios:

  equiv         sharded step ≡ single-device step (unfused / fused /
                accum2+bf16, on data=8 and data=4,model=2 meshes)
  lans          LANS sharded ≡ single-device (fp32 and accum2+bf16): the
                per-slice gradient-norm reductions under GSPMD
  mlm_flash     the paper path: bert-smoke MLM through flash attention,
                fused LAMB and the fused-CE head (plus the dense-head
                variant), sharded ≡ single-device
  stages        mixed-batch fit_stages re-jits correctly on a mesh
  checkpoint    FSDP state saved on data=8 restores onto data=4,model=2
                (values, placements, and a post-restore step)
  crash_resume  preemption/fault injection: nested training subprocesses
                are SIGKILLed mid-training and mid-save (a hook inside the
                checkpoint write path), then resumed — on the same data=8
                mesh (bit-exact loss/metric continuation vs an
                uninterrupted reference) and on data=4,model=2 — with
                crash-consistency checks on the checkpoint directory
                (LATEST never names a partial checkpoint; stray tmp dirs
                are GC'd by the resumed run's first save)
  memory        per-device param+optimizer bytes: FSDP vs unsharded, live
                arrays + compiled per-device argument sizes
  guards        clear errors for non-divisible batches
  nan_skip      in-jit non-finite guard under GSPMD: a NaN-injected batch is
                skipped in-graph (global reduction — every device agrees)
                and the final params are BITWISE equal to a clean run whose
                stream simply omits the poisoned ordinal; both meshes
  spike_rollback  loss-spike watchdog on a mesh: an injected spike trips the
                supervisor, the last validated checkpoint is restored, the
                stream fast-forwards past the suspect window, and the run
                completes with finite loss; both meshes
  sigterm_resume  SIGTERM preemption: a victim gets SIGTERM mid-run, writes
                a final checkpoint inside the grace window, exits rc=0 with
                status=preempted, and a --resume run continues bit-exact vs
                an uninterrupted reference (data=8)

The ``--victim`` mode is the nested training run the crash_resume /
sigterm_resume scenarios kill (or signal) and resume:

    python tests/sharded_harness.py --victim --ckpt-dir D --steps 8 \
        --every 2 --mesh data=8,model=1 [--resume] [--out hist.json] \
        [--kill-after-batches 5 | --kill-at-save 2:3] [--sync-checkpoint] \
        [--term-after-batches 5 --preempt-grace 30] [--skip-nonfinite]
"""
import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import (  # noqa: E402
    checkpoint_step,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import smoke_config  # noqa: E402
from repro.configs.base import ModelConfig, TrainConfig  # noqa: E402
from repro.core import make_stage  # noqa: E402
from repro.data import DataPipeline  # noqa: E402
from repro.launch.mesh import make_mesh_from_spec  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.sharding import shardings_for, train_state_shardings  # noqa: E402
from repro.telemetry import EventLog  # noqa: E402
from repro.train import (  # noqa: E402
    FaultInjector,
    FaultSpec,
    SupervisorConfig,
    Trainer,
)
from repro.train.step import make_train_step  # noqa: E402

TINY = ModelConfig(
    name="tiny-sharded", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
)
MESHES = ("data=8,model=1", "data=4,model=2")
BATCH, SEQ, STEPS = 16, 32, 3


def _fit(cfg, tc, mesh_spec=None, steps=STEPS, batch=BATCH, seq=SEQ):
    mesh = make_mesh_from_spec(mesh_spec) if mesh_spec else None
    model = build_model(cfg)
    tr = Trainer(model, tc, mesh=mesh, log_every=1000, log_fn=lambda s: None)
    data = DataPipeline(cfg, batch, seq, seed=0, mesh=mesh)
    tr.fit(data, steps)
    return tr


def _maxdiff(a, b) -> float:
    # gather to host first: operands may be committed to different meshes
    d = jax.tree.map(
        lambda x, y: float(
            np.max(np.abs(
                np.asarray(x).astype(np.float32)
                - np.asarray(y).astype(np.float32)
            ))
        ),
        a, b,
    )
    return max(jax.tree.leaves(d))


def _equiv_entry(cfg, tc):
    base = _fit(cfg, tc)
    out = {}
    for spec in MESHES:
        tr = _fit(cfg, tc, spec)
        out[spec] = {
            "param_maxdiff": _maxdiff(tr.state.params, base.state.params),
            "loss_diff": abs(
                tr.history[-1]["loss/total"] - base.history[-1]["loss/total"]
            ),
            "loss": tr.history[-1]["loss/total"],
        }
    return out


def scenario_equiv():
    return {
        "unfused": _equiv_entry(
            TINY, TrainConfig(optimizer="lamb", learning_rate=1e-3)
        ),
        "fused": _equiv_entry(
            TINY,
            TrainConfig(optimizer="lamb", learning_rate=1e-3,
                        use_fused_lamb=True),
        ),
        "accum2_bf16": _equiv_entry(
            TINY,
            TrainConfig(optimizer="lamb", learning_rate=1e-3, accum_steps=2,
                        precision="bf16"),
        ),
    }


def scenario_lans():
    """LANS (block-normalized gradient, Nesterov two-term trust-ratio update)
    sharded ≡ single-device — plain fp32 and the accum+bf16 large-batch
    config, on both mesh shapes.  LANS rides the unfused transform chain, so
    this pins the per-slice gradient-norm reductions under GSPMD."""
    return {
        "fp32": _equiv_entry(
            TINY, TrainConfig(optimizer="lans", learning_rate=1e-3)
        ),
        "accum2_bf16": _equiv_entry(
            TINY,
            TrainConfig(optimizer="lans", learning_rate=1e-3, accum_steps=2,
                        precision="bf16"),
        ),
    }


def scenario_mlm_flash():
    # MLM through flash attention; the smoke config inherits bert-large's
    # use_flash_kernel=True AND use_fused_ce_head=True, so "fused_ce" is the
    # full paper path (gather + chunked-vocab CE head, no (B,S,V) logits)
    # and "dense_head" isolates the head swap on the same sharded step
    cfg = smoke_config("bert-large")
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    return {
        "fused_ce": _equiv_entry(cfg, tc),
        "dense_head": _equiv_entry(cfg.replace(use_fused_ce_head=False), tc),
    }


def scenario_stages():
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    mesh = make_mesh_from_spec("data=8,model=1")
    model = build_model(TINY)
    tr = Trainer(model, tc, mesh=mesh, log_every=1000, log_fn=lambda s: None)
    stages = [
        make_stage("s1", SEQ, 16, 2, base_lr=1e-3, base_batch=16,
                   base_warmup_ratio=0.25),
        make_stage("s2", SEQ * 2, 8, 2, base_lr=1e-3, base_batch=16,
                   base_warmup_ratio=0.25),
    ]
    tr.fit_stages(stages)
    return {
        "final_step": int(tr.state.step),
        "final_loss": tr.history[-1]["loss/total"],
        "finite": bool(np.isfinite(tr.history[-1]["loss/total"])),
    }


def scenario_checkpoint(tmpdir="/tmp/sharded_harness_ckpt"):
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    tr = _fit(TINY, tc, "data=8,model=1", steps=2)
    path = save_checkpoint(tmpdir, int(tr.state.step), tr.state)

    # restore the full TrainState onto a *different* mesh shape
    mesh2 = make_mesh_from_spec("data=4,model=2")
    model = build_model(TINY)
    init_fn, step_fn = make_train_step(model, tc)
    abstract = jax.eval_shape(init_fn, jax.random.key(0))
    ssh2 = train_state_shardings(model.defs, abstract, mesh2)
    restored = restore_checkpoint(path, abstract, shardings=ssh2)

    param_maxdiff = _maxdiff(restored.params, tr.state.params)
    moment_maxdiff = _maxdiff(restored.opt_state.mu, tr.state.opt_state.mu)
    # every restored leaf must be committed to its target sharding
    flat_ok = all(
        leaf.sharding == sh
        for leaf, sh in zip(
            jax.tree.leaves(restored.params), jax.tree.leaves(ssh2.params)
        )
    )
    # the restored state must be usable: one more sharded step on mesh2
    tr2 = Trainer(model, tc, mesh=mesh2, log_every=1000, log_fn=lambda s: None)
    tr2.state = restored
    data = DataPipeline(TINY, BATCH, SEQ, seed=1, mesh=mesh2)
    tr2.fit(data, 1)
    return {
        "param_maxdiff": param_maxdiff,
        "moment_maxdiff": moment_maxdiff,
        "shardings_match": bool(flat_ok),
        "post_restore_step": int(tr2.state.step),
        "post_restore_loss_finite": bool(
            np.isfinite(tr2.history[-1]["loss/total"])
        ),
    }


# ---------------------------------------------------------------------------
# preemption / fault injection: SIGKILL a nested training run, resume it
# ---------------------------------------------------------------------------

def _kill_after_batches(data, n: int):
    """Yield ``n`` batches, then SIGKILL the process on the next request —
    a preemption landing at a chosen training step."""
    served = 0
    while True:
        if served >= n:
            os.kill(os.getpid(), signal.SIGKILL)
        served += 1
        yield next(data)


def _term_after_batches(data, n: int):
    """Send the process SIGTERM once, when the ``n``-th batch is requested,
    then keep serving — the *graceful* preemption: the handler sets a flag,
    the in-flight step finishes, the Trainer saves and stops cleanly."""
    served = 0
    while True:
        if served == n:
            os.kill(os.getpid(), signal.SIGTERM)
        served += 1
        yield next(data)


def _arm_mid_save_kill(save_idx: int, leaf_idx: int) -> None:
    """SIGKILL during the ``save_idx``-th checkpoint write of this process,
    once ``leaf_idx`` leaves are on disk — i.e. mid-save, before the atomic
    rename publishes the checkpoint."""
    from repro.checkpoint import io as ckpt_io

    seen = {"saves": 0}

    def hook(i, _tmp):
        if i == 0:
            seen["saves"] += 1
        if seen["saves"] == save_idx and i == leaf_idx:
            os.kill(os.getpid(), signal.SIGKILL)

    ckpt_io.after_leaf_write = hook


def victim(argv) -> None:
    """One nested training run the crash_resume scenario kills / resumes."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--every", type=int, default=2)
    ap.add_argument("--mesh", default=MESHES[0])
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--sync-checkpoint", action="store_true")
    ap.add_argument("--kill-after-batches", type=int, default=None)
    ap.add_argument("--kill-at-save", default=None, metavar="SAVE:LEAF")
    ap.add_argument("--term-after-batches", type=int, default=None)
    ap.add_argument("--preempt-grace", type=float, default=None)
    ap.add_argument("--skip-nonfinite", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    if args.kill_at_save:
        save_idx, leaf_idx = (int(x) for x in args.kill_at_save.split(":"))
        _arm_mid_save_kill(save_idx, leaf_idx)

    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True,
                     skip_nonfinite=args.skip_nonfinite)
    mesh = make_mesh_from_spec(args.mesh)
    tr = Trainer(
        build_model(TINY), tc, mesh=mesh,
        checkpoint_dir=args.ckpt_dir or None, checkpoint_every=args.every,
        async_checkpoint=not args.sync_checkpoint, resume=args.resume,
        preempt_grace=args.preempt_grace,
        log_every=1, log_fn=lambda s: None,
    )
    data = DataPipeline(TINY, BATCH, SEQ, seed=0, mesh=mesh)
    if args.kill_after_batches is not None:
        data = _kill_after_batches(data, args.kill_after_batches)
    if args.term_after_batches is not None:
        data = _term_after_batches(data, args.term_after_batches)
    tr.fit(data, args.steps)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": tr.history,
                       "final_step": int(tr.state.step),
                       "skipped": int(tr.state.skipped),
                       "status": tr._status,
                       "examples_seen": tr.examples_seen}, f)


def _run_victim(*args, expect_kill=False, timeout=600):
    cmd = [sys.executable, os.path.abspath(__file__), "--victim",
           *map(str, args)]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
    if expect_kill:
        if proc.returncode != -signal.SIGKILL:
            raise RuntimeError(
                f"victim survived (rc={proc.returncode}):\n"
                f"{proc.stderr[-3000:]}"
            )
    elif proc.returncode != 0:
        raise RuntimeError(
            f"victim failed (rc={proc.returncode}):\n{proc.stderr[-3000:]}"
        )
    return proc


def _history_rows(blob, after_step):
    """History rows past ``after_step``, minus wall-clock (machine noise)."""
    return [
        {k: v for k, v in row.items() if k != "wall_s"}
        for row in blob["history"] if row["step"] > after_step
    ]


def _stray_tmp_count(ckpt_dir):
    return sum(n.startswith(".tmp_ckpt_") for n in os.listdir(ckpt_dir))


def scenario_crash_resume(steps=8, every=2):
    """Kill-and-resume: the acceptance gate for crash-safe training.

    An uninterrupted reference run (no checkpointing) fixes the ground-truth
    loss/metric history.  Victims are SIGKILLed mid-training and mid-save,
    resumed from the latest *persisted* checkpoint on the same data=8 mesh
    (history must be bit-exact vs the reference from the restored step on)
    and on a data=4,model=2 mesh (allclose — cross-mesh reduction order),
    with crash-consistency checks on the directory in between.
    """
    mesh_a, mesh_b = MESHES
    results = {}
    with tempfile.TemporaryDirectory() as root:
        ref_json = os.path.join(root, "ref.json")
        _run_victim("--steps", steps, "--mesh", mesh_a, "--out", ref_json)
        with open(ref_json) as f:
            ref = json.load(f)

        def crash_then_inspect(name, *kill_args):
            ckpt = os.path.join(root, name)
            _run_victim("--ckpt-dir", ckpt, "--steps", steps,
                        "--every", every, "--mesh", mesh_a, *kill_args,
                        expect_kill=True)
            latest = latest_checkpoint(ckpt)
            with open(os.path.join(ckpt, "LATEST")) as f:
                pointed = os.path.join(ckpt, f.read().strip())
            return ckpt, {
                "latest_step": (None if latest is None
                                else checkpoint_step(latest)),
                "pointer_names_complete": os.path.isfile(
                    os.path.join(pointed, "manifest.json")),
                "stray_tmp_dirs": _stray_tmp_count(ckpt),
            }

        def resume_and_compare(ckpt, entry, mesh):
            res_json = ckpt + f"_resume_{mesh.replace('=', '').replace(',', '_')}.json"
            _run_victim("--ckpt-dir", ckpt, "--steps", steps,
                        "--every", every, "--mesh", mesh, "--resume",
                        "--out", res_json)
            with open(res_json) as f:
                res = json.load(f)
            start = entry["latest_step"]
            rows, ref_rows = _history_rows(res, start), _history_rows(ref, start)
            return {
                "resumed_rows": len(rows),
                "steps_match": ([r["step"] for r in rows]
                                == [r["step"] for r in ref_rows]),
                "bitexact": rows == ref_rows,
                "loss_maxdiff": max(
                    abs(a["loss/total"] - b["loss/total"])
                    for a, b in zip(rows, ref_rows)),
                "final_step": res["final_step"],
                "examples_seen_match": (res["examples_seen"]
                                        == ref["examples_seen"]),
                "tmp_gc_after_resume": _stray_tmp_count(ckpt) == 0,
                "final_latest_step": checkpoint_step(latest_checkpoint(ckpt)),
            }

        # -- preemption mid-training: SIGKILL when step 8's batch is pulled
        ckpt1, e1 = crash_then_inspect(
            "mid_training", "--kill-after-batches", steps - 1)
        ckpt1_copy = ckpt1 + "_meshb"
        shutil.copytree(ckpt1, ckpt1_copy)  # B-mesh resume gets a pristine dir
        e1["resume_same_mesh"] = resume_and_compare(ckpt1, e1, mesh_a)
        e1["resume_other_mesh"] = resume_and_compare(
            ckpt1_copy, {"latest_step": e1["latest_step"]}, mesh_b)
        results["mid_training"] = e1

        # -- crash mid-save: die inside the 2nd checkpoint write (step 2*every
        #    stays partial; LATEST must keep naming the complete step `every`)
        ckpt2, e2 = crash_then_inspect("mid_save", "--kill-at-save", "2:3")
        e2["resume_same_mesh"] = resume_and_compare(ckpt2, e2, mesh_a)
        results["mid_save"] = e2
    return results


# ---------------------------------------------------------------------------
# numerical faults: skip-step guard, loss-spike rollback, SIGTERM preemption
# ---------------------------------------------------------------------------

def _drop_ordinal(data, k: int):
    """Yield ``data``'s batches with the ``k``-th one silently omitted —
    the reference stream a guard-skipped run must match exactly."""
    for i, batch in enumerate(data):
        if i != k:
            yield batch


def scenario_nan_skip(steps=6, poison_at=2):
    """Guard equivalence under GSPMD: a NaN-injected run with the guard on
    must land BITWISE on the params of a clean run whose stream omits the
    poisoned ordinal (the skipped step must be a true no-op, and the
    all-finite verdict must be globally uniform across devices)."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True,
                     skip_nonfinite=True)
    out = {}
    for spec in MESHES:
        mesh = make_mesh_from_spec(spec)
        model = build_model(TINY)

        inj = FaultInjector([FaultSpec("grad_nan", at=poison_at)])
        tr = Trainer(model, tc, mesh=mesh, log_every=1000, log_fn=lambda s: None)
        tr.fit(inj.wrap(DataPipeline(TINY, BATCH, SEQ, seed=0, mesh=mesh)),
               steps)

        clean = Trainer(model, tc, mesh=mesh, log_every=1000,
                        log_fn=lambda s: None)
        clean.fit(_drop_ordinal(DataPipeline(TINY, BATCH, SEQ, seed=0,
                                             mesh=mesh), poison_at),
                  steps - 1)

        out[spec] = {
            "skipped": int(tr.state.skipped),
            "final_step": int(tr.state.step),
            "param_maxdiff": _maxdiff(tr.state.params, clean.state.params),
            "moment_maxdiff": _maxdiff(tr.state.opt_state.mu,
                                       clean.state.opt_state.mu),
            "steps_match": int(tr.state.step) == int(clean.state.step),
        }
    return out


def scenario_spike_rollback(steps=10, every=2, spike_at=5):
    """Watchdog end-to-end on a mesh: injected loss spike -> supervisor trip
    -> restore last validated checkpoint -> fast-forward past the suspect
    window -> finish with finite loss.  The rollback event carries the
    restore arithmetic the report aggregates."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    out = {}
    for spec in MESHES:
        mesh = make_mesh_from_spec(spec)
        model = build_model(TINY)
        inj = FaultInjector([FaultSpec("loss_spike", at=spike_at, scale=100.0)])
        log = EventLog.memory()
        with tempfile.TemporaryDirectory() as ckpt:
            def make_data():
                return inj.wrap(DataPipeline(TINY, BATCH, SEQ, seed=0,
                                             mesh=mesh))

            tr = Trainer(model, tc, mesh=mesh, checkpoint_dir=ckpt,
                         checkpoint_every=every,
                         supervisor=SupervisorConfig(spike_window=8,
                                                     min_history=3),
                         telemetry=log, log_every=1, log_fn=lambda s: None)
            tr.fit(make_data(), steps, data_factory=make_data)
        rollbacks = [e for e in log.events if e["event"] == "rollback"]
        end = [e for e in log.events if e["event"] == "run_end"][-1]
        dropped = sum(e["batches_dropped"] for e in rollbacks)
        out[spec] = {
            "rollbacks": len(rollbacks),
            "reason": rollbacks[0]["reason"] if rollbacks else None,
            "restored_step": rollbacks[0]["step"] if rollbacks else None,
            "from_step": rollbacks[0]["from_step"] if rollbacks else None,
            "final_step": int(tr.state.step),
            # every batch is either trained or explicitly dropped
            "step_arithmetic_ok": int(tr.state.step) == steps - dropped,
            "final_loss": tr.history[-1]["loss/total"],
            "final_loss_finite": bool(
                np.isfinite(tr.history[-1]["loss/total"])),
            "status": end["status"],
        }
    return out


def scenario_sigterm_resume(steps=8, every=3, term_at=5):
    """Graceful preemption on data=8: SIGTERM mid-run -> grace-window final
    save -> clean exit (rc=0, status=preempted) -> --resume continues
    bit-exact vs an uninterrupted reference."""
    mesh = MESHES[0]
    with tempfile.TemporaryDirectory() as root:
        ref_json = os.path.join(root, "ref.json")
        _run_victim("--steps", steps, "--mesh", mesh, "--out", ref_json)
        with open(ref_json) as f:
            ref = json.load(f)

        ckpt = os.path.join(root, "ckpt")
        pre_json = os.path.join(root, "pre.json")
        _run_victim("--ckpt-dir", ckpt, "--steps", steps, "--every", every,
                    "--mesh", mesh, "--term-after-batches", term_at,
                    "--preempt-grace", 60, "--out", pre_json)
        with open(pre_json) as f:
            pre = json.load(f)
        latest = checkpoint_step(latest_checkpoint(ckpt))

        res_json = os.path.join(root, "res.json")
        _run_victim("--ckpt-dir", ckpt, "--steps", steps, "--every", every,
                    "--mesh", mesh, "--resume", "--out", res_json)
        with open(res_json) as f:
            res = json.load(f)
        rows = _history_rows(res, latest)
        ref_rows = _history_rows(ref, latest)
        return {
            "preempt_status": pre["status"],
            "preempt_final_step": pre["final_step"],
            "stopped_early": pre["final_step"] < steps,
            "saved_at_preempt_step": latest == pre["final_step"],
            "resumed_rows": len(rows),
            "bitexact": rows == ref_rows,
            "final_step": res["final_step"],
            "resume_status": res["status"],
        }


def scenario_memory():
    from repro.sharding import per_device_state_bytes

    cfg = smoke_config("bert-large")
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True)
    sharded = _fit(cfg, tc, "data=8,model=1", steps=1)
    single = _fit(cfg, tc, steps=1)

    fsdp = per_device_state_bytes(sharded.state.params) + per_device_state_bytes(
        sharded.state.opt_state
    )
    base = per_device_state_bytes(single.state.params) + per_device_state_bytes(
        single.state.opt_state
    )

    def compiled_arg_bytes(tr, batch):
        try:
            c = tr._step_fn.lower(tr.state, tr._place_batch(batch)).compile()
            ma = c.memory_analysis()
            return {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
            }
        except Exception as e:  # memory_analysis is backend-dependent
            return {"error": f"{type(e).__name__}: {e}"}

    batch = next(DataPipeline(cfg, BATCH, SEQ, seed=0))
    return {
        "fsdp_per_device_state_bytes": fsdp,
        "single_device_state_bytes": base,
        "state_ratio": base / max(fsdp, 1),
        "compiled_sharded": compiled_arg_bytes(sharded, batch),
        "compiled_single": compiled_arg_bytes(single, batch),
    }


def scenario_guards():
    out = {}
    try:
        DataPipeline(TINY, 6, SEQ, mesh=make_mesh_from_spec("data=4,model=2"))
        out["pipeline_raises"] = False
    except ValueError as e:
        out["pipeline_raises"] = True
        out["pipeline_msg"] = str(e)
    try:
        tc = TrainConfig(optimizer="lamb")
        tr = Trainer(build_model(TINY), tc,
                     mesh=make_mesh_from_spec("data=8,model=1"),
                     log_fn=lambda s: None)
        tr.init()
        tr._place_batch({"tokens": np.zeros((6, SEQ), np.int32)})
        out["trainer_raises"] = False
    except ValueError as e:
        out["trainer_raises"] = True
        out["trainer_msg"] = str(e)
    return out


SCENARIOS = {
    "equiv": scenario_equiv,
    "lans": scenario_lans,
    "mlm_flash": scenario_mlm_flash,
    "stages": scenario_stages,
    "checkpoint": scenario_checkpoint,
    "crash_resume": scenario_crash_resume,
    "nan_skip": scenario_nan_skip,
    "spike_rollback": scenario_spike_rollback,
    "sigterm_resume": scenario_sigterm_resume,
    "memory": scenario_memory,
    "guards": scenario_guards,
}


def main(argv):
    if argv and argv[0] == "--victim":
        victim(argv[1:])
        return
    names = argv or list(SCENARIOS)
    out = {"devices": len(jax.devices())}
    for name in names:
        out[name] = SCENARIOS[name]()
    print(json.dumps(out))


if __name__ == "__main__":
    main(sys.argv[1:])
