"""Convergence-harness tier: the bench protocol on 8 virtual devices.

The convergence bench's claims (steps-to-target vs global batch) are only
meaningful if the protocol underneath them is deterministic: the synthetic
MLM stream must be a pure function of its seed, and the logged loss
trajectory must not depend on *how* the global batch is laid out — mesh
shape or gradient-accumulation split.  This harness pins exactly that, as a
subprocess (XLA_FLAGS must force the 8 virtual CPU devices before jax
import; same pattern as tests/sharded_harness.py).

    PYTHONPATH=src python tests/convergence_harness.py [scenario ...]

Prints one JSON object on the last stdout line.  Scenarios:

  stream          synthetic-MLM stream seed-stability: same seed → bitwise
                  identical batches, different seed → different batches
  seed_stability  protocol.train_once through the fused stack is bitwise
                  reproducible under re-run, and its loss trajectory is
                  stable (allclose) across mesh shapes (data=8 vs
                  data=4,model=2) and accum settings (1 vs 2); a different
                  data seed must move the trajectory
  target          steps_to_target on a real trajectory: agrees with a
                  recomputation from the logged rows, the first row's loss
                  is its own crossing, an unreachable target is None
  two_stage       protocol.train_stages on a mesh: both stages appear in
                  the history with a cumulative step counter (the §4.1
                  stage-2 re-warm-up path), finite train/eval loss
"""
import json
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)                       # benchmarks.*
sys.path.insert(0, os.path.join(ROOT, "src"))  # repro.*

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import protocol  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import make_stage  # noqa: E402
from repro.launch.mesh import make_mesh_from_spec  # noqa: E402

TINY = ModelConfig(
    name="tiny-convergence", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, tie_embeddings=True,
)
MESHES = ("data=8,model=1", "data=4,model=2")
BATCH, SEQ, STEPS = 16, 32, 4
TARGET = 5.5  # just under the ln(256) ≈ 5.55 initial MLM loss


def _losses(run):
    return [row["loss"] for row in run["history"]]


def _run(mesh_spec, accum, seed=0, steps=STEPS):
    return protocol.train_once(
        TINY, optimizer="lamb", batch=BATCH, seq=SEQ, steps=steps,
        lr=1e-3, warmup_ratio=0.5, seed=seed, eval_batches=2,
        accum_steps=accum, mesh=make_mesh_from_spec(mesh_spec),
        log_every=1, target_loss=TARGET,
    )


def scenario_stream():
    it_a, _ = protocol.synthetic_stream(TINY, BATCH, SEQ, seed=0)
    it_b, _ = protocol.synthetic_stream(TINY, BATCH, SEQ, seed=0)
    it_c, _ = protocol.synthetic_stream(TINY, BATCH, SEQ, seed=7)
    same, diff = True, False
    fields = None
    for _ in range(3):
        a, b, c = next(it_a), next(it_b), next(it_c)
        fields = sorted(a)
        same &= all(np.array_equal(a[k], b[k]) for k in a)
        diff |= any(not np.array_equal(a[k], c[k]) for k in a)
    return {"same_seed_bitwise": bool(same),
            "diff_seed_differs": bool(diff),
            "fields": fields}


def scenario_seed_stability():
    ref = _run(MESHES[0], 1)
    rerun = _run(MESHES[0], 1)
    out = {
        "rerun_bitwise": _losses(ref) == _losses(rerun),
        "ref_losses": _losses(ref),
        "variants": {},
    }
    # same global batch, different layouts: other mesh shape, accum split,
    # and both at once — the trajectory must not move past reduction noise
    for spec, accum in ((MESHES[1], 1), (MESHES[0], 2), (MESHES[1], 2)):
        r = _run(spec, accum)
        out["variants"][f"{spec}|accum{accum}"] = {
            "loss_maxdiff": max(
                abs(x - y) for x, y in zip(_losses(r), _losses(ref))
            ),
            "steps_match": ([row["step"] for row in r["history"]]
                            == [row["step"] for row in ref["history"]]),
        }
    out["diff_seed_differs"] = _losses(_run(MESHES[0], 1, seed=3)) != _losses(ref)
    return out


def scenario_target():
    r = _run(MESHES[0], 1, steps=5)
    rows = [{"step": h["step"], "loss/total": h["loss"]} for h in r["history"]]
    crossing = next(
        (h["step"] for h in r["history"] if h["loss"] <= TARGET), None
    )
    return {
        "steps_to_target": r["steps_to_target"],
        "consistent": r["steps_to_target"] == crossing,
        "first_row_is_own_crossing": (
            protocol.steps_to_target(rows, r["history"][0]["loss"])
            == r["history"][0]["step"]
        ),
        "unreachable_is_none": protocol.steps_to_target(rows, 0.1) is None,
        "history_len": len(r["history"]),
    }


def scenario_two_stage():
    stages = [
        make_stage("s1", SEQ, BATCH, 3, base_lr=1e-3, base_batch=BATCH,
                   base_warmup_ratio=1 / 3),
        make_stage("s2", SEQ * 2, BATCH // 2, 3, base_lr=1e-3,
                   base_batch=BATCH, base_warmup_ratio=1 / 3),
    ]
    r = protocol.train_stages(
        TINY, optimizer="lamb", stages=stages,
        mesh=make_mesh_from_spec(MESHES[0]), eval_batches=2, log_every=1,
    )
    return {
        "stages_seen": sorted({row.get("stage", -1) for row in r["history"]}),
        "stage2_rows": sum(1 for row in r["history"] if row.get("stage") == 1),
        "total_steps": r["steps"],
        "final_step": r["history"][-1]["step"],
        "final_loss_finite": bool(np.isfinite(r["train_loss"])),
        "eval_loss_finite": bool(np.isfinite(r["eval_loss"])),
    }


SCENARIOS = {
    "stream": scenario_stream,
    "seed_stability": scenario_seed_stability,
    "target": scenario_target,
    "two_stage": scenario_two_stage,
}


def main(argv):
    names = argv or list(SCENARIOS)
    out = {"devices": len(jax.devices())}
    for name in names:
        out[name] = SCENARIOS[name]()
    print(json.dumps(out))


if __name__ == "__main__":
    main(sys.argv[1:])
