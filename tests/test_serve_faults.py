"""Serving reliability layer: fault injection, retries, quarantine,
timeouts, stall-watchdog degrade, graceful drain, terminal-state invariant.

The serving twin of ``test_train_faults``: every scenario runs the real
``ContinuousEngine`` over a tiny model with a deterministic
``ServeFaultInjector``, then asserts on typed terminal states and the
telemetry lifecycle events."""
import jax
import numpy as np
import pytest

from conftest import tiny_dense
from repro.models import build_model
from repro.serve import (
    ContinuousEngine,
    FCFSScheduler,
    RequestStatus,
    ServeFaultInjector,
    ServeFaultSpec,
    ServeRequest,
    parse_fault_specs,
)
from repro.telemetry import EventLog, RunReport


@pytest.fixture(scope="module")
def served():
    model = build_model(tiny_dense())
    params = model.init(jax.random.key(0))
    return model, params


def _reqs(n, *, max_new=4, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(rng.integers(0, 256, size=8).astype(np.int32),
                     max_new_tokens=max_new, rid=i, **kw)
        for i in range(n)
    ]


def _counts(reqs):
    return {
        s.value: sum(1 for r in reqs if r.status is s)
        for s in (RequestStatus.COMPLETED, RequestStatus.SHED,
                  RequestStatus.TIMED_OUT, RequestStatus.FAILED)
    }


# ---------------------------------------------------------------------------
# injector unit behaviour
# ---------------------------------------------------------------------------

def test_injector_once_semantics_and_replay():
    inj = ServeFaultInjector([ServeFaultSpec("sample_nan", at=3)])
    assert inj.fire_request(2) is None
    assert inj.fire_request(3) == "sample_nan"
    assert inj.fire_request(3) is None          # once: the retry succeeds
    inj.reset()
    assert inj.fire_request(3) == "sample_nan"  # replay is identical


def test_injector_persistent_and_priority():
    inj = ServeFaultInjector([
        ServeFaultSpec("sample_nan", at=1, once=False),
        ServeFaultSpec("slot_corrupt", at=1),
    ])
    # the stronger failure decides the slot's fate; at most one per call
    assert inj.fire_request(1) == "slot_corrupt"
    assert inj.fire_request(1) == "sample_nan"  # persistent keeps firing
    assert inj.fire_request(1) == "sample_nan"
    assert inj.fire_counts() == {"slot_corrupt": 1, "sample_nan": 2}


def test_injector_stall_keyed_by_step_ordinal():
    inj = ServeFaultInjector([
        ServeFaultSpec("decode_stall", at=2, stall_s=0.1),
        ServeFaultSpec("decode_stall", at=-1, stall_s=0.01, once=False),
    ])
    assert inj.stall_s(0) == pytest.approx(0.01)
    assert inj.stall_s(2) == pytest.approx(0.11)  # matching specs sum
    assert inj.stall_s(2) == pytest.approx(0.01)  # once spec already fired


def test_parse_fault_specs():
    specs = parse_fault_specs(
        "sample_nan@1,slot_corrupt@2:persist,decode_stall@3:stall=0.2")
    assert [(s.kind, s.at, s.once) for s in specs] == [
        ("sample_nan", 1, True), ("slot_corrupt", 2, False),
        ("decode_stall", 3, True)]
    assert specs[2].stall_s == pytest.approx(0.2)
    with pytest.raises(ValueError, match="kind@ordinal"):
        parse_fault_specs("sample_nan")
    with pytest.raises(ValueError, match="unknown serve fault kind"):
        parse_fault_specs("oom@1")
    with pytest.raises(ValueError, match="option"):
        parse_fault_specs("sample_nan@1:never")


# ---------------------------------------------------------------------------
# engine: retries, quarantine, exhaustion
# ---------------------------------------------------------------------------

def test_transient_fault_retries_then_completes(served):
    """A once-fault frees the slot and requeues the request; the retry
    completes with the same tokens an unfaulted run produces."""
    model, params = served
    ref = ContinuousEngine(model, params, n_slots=2, max_len=32).generate(
        _reqs(3))
    log = EventLog.memory()
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32, telemetry=log,
        faults=ServeFaultInjector([ServeFaultSpec("sample_nan", at=1)]))
    out = eng.generate(_reqs(3))
    assert _counts(out) == {"completed": 3, "shed": 0, "timed_out": 0,
                            "failed": 0}
    assert out[1].attempts == 2
    assert [e["rid"] for e in log.events if e["event"] == "serve_retry"] == [1]
    for r, s in zip(out, ref):  # greedy: the retry changes nothing
        assert r.out_tokens == s.out_tokens
    assert eng.pool.n_free == 2


def test_retry_budget_exhaustion_fails_not_drops(served):
    model, params = served
    log = EventLog.memory()
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32, telemetry=log, max_retries=2,
        faults=ServeFaultInjector(
            [ServeFaultSpec("sample_nan", at=0, once=False)]))
    out = eng.generate(_reqs(2))
    assert out[0].status is RequestStatus.FAILED
    assert out[0].fail_reason == "sample_nan"
    assert not out[0].dropped            # failed is surfaced, not a drop
    assert out[0].attempts == 3          # 1 try + 2 retries
    assert out[1].status is RequestStatus.COMPLETED
    retries = [e for e in log.events if e["event"] == "serve_retry"]
    assert [e["attempt"] for e in retries] == [1, 2]
    terminal = [e for e in log.events if e["event"] == "serve_request"]
    assert sorted(e["status"] for e in terminal) == ["completed", "failed"]


def test_slot_corruption_quarantines_and_recovers(served):
    """slot_corrupt evicts the slot *out of* the free list for a cooldown;
    the request retries on another slot and the pool ends whole."""
    model, params = served
    log = EventLog.memory()
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32, telemetry=log,
        quarantine_steps=2,
        faults=ServeFaultInjector([ServeFaultSpec("slot_corrupt", at=0)]))
    out = eng.generate(_reqs(3, max_new=6))
    assert _counts(out)["completed"] == 3
    quar = [e for e in log.events if e["event"] == "serve_quarantine"]
    assert len(quar) == 1 and quar[0]["rid"] == 0
    assert eng.pool.n_free == 2  # quarantine released by the end


def test_quarantine_cannot_deadlock_single_slot(served):
    """With every slot quarantined and work still queued, the engine must
    force-release rather than wait for decode steps that can never run."""
    model, params = served
    eng = ContinuousEngine(
        model, params, n_slots=1, max_len=32, quarantine_steps=1000,
        faults=ServeFaultInjector([ServeFaultSpec("slot_corrupt", at=0)]))
    out = eng.generate(_reqs(2))
    assert _counts(out)["completed"] == 2
    assert eng.pool.n_free == 1


# ---------------------------------------------------------------------------
# engine: timeouts free the slot
# ---------------------------------------------------------------------------

def test_decode_timeout_frees_slot_for_next_request(served):
    """A running request past its latency budget is cancelled at the next
    step boundary; its slot is reused and n_free is restored at drain."""
    model, params = served
    log = EventLog.memory()
    # persistent stall makes every decode step >= 10ms, so the 30ms budget
    # expires mid-decode long before 40 new tokens could finish
    eng = ContinuousEngine(
        model, params, n_slots=1, max_len=64, telemetry=log,
        faults=ServeFaultInjector(
            [ServeFaultSpec("decode_stall", at=-1, stall_s=0.01,
                            once=False)]))
    slow = ServeRequest(np.zeros(8, np.int32), max_new_tokens=40,
                        timeout_s=0.03, rid=0)
    quick = ServeRequest(np.zeros(8, np.int32), max_new_tokens=2, rid=1)
    out = eng.generate([slow, quick])
    assert out[0].status is RequestStatus.TIMED_OUT and out[0].dropped
    assert 0 < len(out[0].out_tokens) < 40      # cancelled mid-decode
    assert out[1].status is RequestStatus.COMPLETED  # slot was reusable
    assert eng.pool.n_free == 1
    t = [e for e in log.events if e["event"] == "serve_timeout"]
    assert len(t) == 1 and t[0]["where"] == "decode"


# ---------------------------------------------------------------------------
# engine: stall watchdog degrades admissions
# ---------------------------------------------------------------------------

def test_stall_watchdog_degrades_new_admissions(served):
    """A decode step past the SLO flips degraded mode: later admissions get
    max_new_tokens capped, and the serve_degraded event fires."""
    model, params = served
    log = EventLog.memory()
    eng = ContinuousEngine(
        model, params, n_slots=1, max_len=64, telemetry=log,
        stall_slo_s=0.05, degrade_max_new_tokens=2,
        degrade_recovery_steps=10_000,
        faults=ServeFaultInjector(
            [ServeFaultSpec("decode_stall", at=0, stall_s=0.2)]))
    out = eng.generate(_reqs(2, max_new=8))
    degraded = [e for e in log.events if e["event"] == "serve_degraded"]
    assert degraded and degraded[0]["active"] is True
    assert len(out[0].out_tokens) == 8  # already admitted: budget untouched
    assert len(out[1].out_tokens) == 2  # admitted degraded: capped
    assert all(r.status is RequestStatus.COMPLETED for r in out)


# ---------------------------------------------------------------------------
# engine: graceful drain
# ---------------------------------------------------------------------------

def test_drain_under_load_finishes_inflight_sheds_queue(served):
    """Drain stops admissions and sheds the backlog while the in-flight
    request finishes inside the grace window."""
    model, params = served
    log = EventLog.memory()
    eng = ContinuousEngine(model, params, n_slots=1, max_len=32,
                           telemetry=log)
    flag = {"drain": False}
    out = eng.generate(
        _reqs(4, max_new=6),
        on_token=lambda r, t: flag.__setitem__("drain", True),
        should_drain=lambda: flag["drain"],
        drain_grace_s=30.0,
    )
    assert _counts(out) == {"completed": 1, "shed": 3, "timed_out": 0,
                            "failed": 0}
    assert all(r.shed_reason == "drain" for r in out[1:])
    drains = [e for e in log.events if e["event"] == "serve_drain"]
    assert len(drains) == 1 and drains[0]["queued"] == 3
    assert drains[0]["in_flight"] == 1
    assert eng.pool.n_free == 1


def test_drain_grace_expiry_sheds_inflight(served):
    """Past the grace deadline even in-flight work is shed — the process
    must be able to exit."""
    model, params = served
    eng = ContinuousEngine(model, params, n_slots=1, max_len=64)
    flag = {"drain": False}
    out = eng.generate(
        _reqs(2, max_new=40),
        on_token=lambda r, t: flag.__setitem__("drain", True),
        should_drain=lambda: flag["drain"],
        drain_grace_s=0.0,
    )
    assert all(r.status is RequestStatus.SHED for r in out)
    assert out[0].out_tokens  # was genuinely in flight when shed
    assert eng.pool.n_free == 1


# ---------------------------------------------------------------------------
# invariant: every request ends in exactly one terminal state
# ---------------------------------------------------------------------------

def test_every_request_one_terminal_state_under_chaos(served):
    """Overload + deadline pressure + mixed faults: the four terminal
    counters stay disjoint and sum to the submitted total, and a replay
    reproduces them exactly."""
    model, params = served
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32,
        scheduler=FCFSScheduler(max_queue=2),
        faults=ServeFaultInjector([
            # keyed to the head of the line: with max_queue=2 and a closed
            # batch only the two oldest arrivals survive the first sweep
            ServeFaultSpec("slot_corrupt", at=0),
            ServeFaultSpec("sample_nan", at=1, once=False),
        ]))
    first = None
    for _ in range(2):
        eng.faults.reset()
        eng.scheduler = FCFSScheduler(max_queue=2)
        out = eng.generate(_reqs(8, max_new=6))
        counts = _counts(out)
        assert sum(counts.values()) == 8
        assert counts["failed"] == 1 and counts["shed"] > 0
        # each request is in exactly one bucket (states are disjoint)
        for r in out:
            assert [r.status is s for s in (
                RequestStatus.COMPLETED, RequestStatus.SHED,
                RequestStatus.TIMED_OUT, RequestStatus.FAILED,
            )].count(True) == 1
        if first is None:
            first = counts
    assert counts == first  # deterministic, replayable


def test_nonterminal_roster_raises(served):
    """generate() refuses to return a request in a non-terminal state —
    the accounting bug surfaces loudly, not as a silent drop."""
    model, params = served
    eng = ContinuousEngine(model, params, n_slots=1, max_len=32)
    req = eng.submit(ServeRequest(np.zeros(4, np.int32), max_new_tokens=2))
    eng.scheduler._queue.clear()   # simulate a scheduler that loses a request
    eng.scheduler._keys.clear()
    with pytest.raises(RuntimeError, match="non-terminal"):
        eng.generate()


# ---------------------------------------------------------------------------
# telemetry folding
# ---------------------------------------------------------------------------

def test_report_folds_serve_lifecycle(served):
    model, params = served
    log = EventLog.memory()
    eng = ContinuousEngine(
        model, params, n_slots=2, max_len=32, telemetry=log,
        faults=ServeFaultInjector([
            ServeFaultSpec("sample_nan", at=0),
            ServeFaultSpec("slot_corrupt", at=1, once=False),
        ]),
        max_retries=1)
    out = eng.generate(_reqs(4))
    report = RunReport.from_events(log).report
    serve = report["serve"]
    assert serve["by_status"] == _counts(out)
    assert sum(serve["by_status"].values()) == serve["requests"] == 4
    assert serve["lifecycle"]["retries"] == 2   # nan retry + corrupt retry
    assert serve["lifecycle"]["quarantines"] == 2
    assert serve["stats"]["failed"] == 1
    assert serve["stats"]["submitted"] == 4
