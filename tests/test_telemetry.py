"""Telemetry subsystem: event schema, span timers, trust-ratio recorder,
serve counters, and the regression-gated run report.

The two load-bearing guarantees:

* **zero-overhead null sink** — with telemetry off the Trainer's metrics
  history is identical (modulo wall-clock fields) to a telemetry-on run's,
  and the step function contains no extra host syncs;
* **recorder ≡ oracle** — the per-layer trust ratios threaded out of the
  fused-LAMB kernels match a hand-computed numpy ``phi(||w||)/||u||`` at
  step 1 from zero moments, and the unfused recorder matches the post-hoc
  ``phi(||w||)/||Δw||`` diagnostic recomputed from the actual deltas.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.kernels import fused_lamb_init, make_fused_lamb_step
from repro.models import build_model
from repro.telemetry import (
    EVENT_TYPES,
    EventLog,
    RunReport,
    SpanRecorder,
    TrustRecorder,
    read_events,
    run_provenance,
    validate_event,
)
from repro.telemetry.trust import PER_LAYER_KEY
from repro.train import Trainer, make_train_step
from tests.conftest import tiny_dense


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------

def test_event_log_jsonl_roundtrip(tmp_path):
    log = EventLog.to_dir(tmp_path)
    log.emit("run_start", provenance=run_provenance(), arch="tiny")
    log.emit("step", step=10, metrics={"loss/total": 1.5})
    log.emit("span", name="step", seconds=0.25, count=10)
    log.emit("checkpoint", step=10, path=str(tmp_path))
    log.emit("run_end", status="ok")
    log.close()

    events = read_events(tmp_path / "events.jsonl")
    assert [e["event"] for e in events] == [
        "run_start", "step", "span", "checkpoint", "run_end"]
    assert [e["seq"] for e in events] == list(range(5))
    assert events[1]["metrics"]["loss/total"] == 1.5
    assert events[0]["provenance"]["git_sha"]
    # appended, not truncated: a second log continues the file
    log2 = EventLog(tmp_path / "events.jsonl")
    log2.emit("run_end", status="again")
    log2.close()
    assert len(read_events(tmp_path / "events.jsonl")) == 6


def test_event_schema_rejects_bad_events():
    log = EventLog.memory()
    with pytest.raises(ValueError, match="unknown event type"):
        log.emit("not_a_type", anything=1)
    with pytest.raises(ValueError, match="missing required fields"):
        log.emit("span", name="no-seconds")
    with pytest.raises(ValueError, match="missing required fields"):
        log.emit("run_start")  # no provenance
    for etype in EVENT_TYPES:
        # every type's required fields are themselves valid
        fields = {f: 0 for f in
                  __import__("repro.telemetry.events",
                             fromlist=["REQUIRED_FIELDS"]).REQUIRED_FIELDS[etype]}
        validate_event({"event": etype, **fields})


def test_null_sink_is_noop(tmp_path):
    log = EventLog()
    assert not log.enabled
    # emit never validates or serializes: junk args must not raise
    assert log.emit("not_even_a_type", junk=object()) is None
    assert log.events == []
    assert list(tmp_path.iterdir()) == []


# ---------------------------------------------------------------------------
# span timers
# ---------------------------------------------------------------------------

def test_span_timer_syncs_async_dispatch():
    spans = SpanRecorder(log=EventLog.memory())
    f = jax.jit(lambda x: (x @ x).sum())
    x = jnp.ones((256, 256))
    float(f(x))  # compile outside any span

    with spans.span("mm", sync=x) as sp:
        out = None
        for _ in range(4):
            out = f(x)
        sp.block_on(out)
        sp.count = 4
    s = spans.summary()["mm"]
    assert s["count"] == 4
    assert s["total_s"] > 0
    assert s["mean_s"] == pytest.approx(s["total_s"] / 4)
    ev = spans.log.events[0]
    assert ev["event"] == "span" and ev["count"] == 4


def test_span_phase_style_and_errors():
    spans = SpanRecorder()
    spans.start("step")
    dt = spans.stop("step", count=2)
    assert dt >= 0
    with pytest.raises(ValueError, match="never started"):
        spans.stop("step")
    assert spans.summary()["step"]["count"] == 2


# ---------------------------------------------------------------------------
# trust-ratio recorder vs hand-computed oracles
# ---------------------------------------------------------------------------

def _lamb_oracle_ratio(x, g, *, eps, wd, layer_axis=None):
    """numpy phi(||w||)/||u|| at step 1 from zero moments (bias-corrected:
    m_hat = g, sqrt(v_hat) = |g|)."""
    x = np.asarray(x, np.float64)
    g = np.asarray(g, np.float64)
    r = g / (np.abs(g) + eps)
    u = r + wd * x
    if layer_axis is None:
        axes = tuple(range(x.ndim))
    else:
        axes = tuple(i for i in range(x.ndim) if i != layer_axis)
    w_norm = np.sqrt((x * x).sum(axis=axes))
    u_norm = np.sqrt((u * u).sum(axis=axes))
    return w_norm / u_norm


def test_fused_aux_ratio_matches_numpy_oracle():
    """The kernel's threaded-out aux ratio IS the applied ratio — checked
    against a from-scratch numpy LAMB on a stacked + unstacked leaf pair."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(2, 3, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(2, 3, 4)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    eps, wd = 1e-6, 0.01
    step = make_fused_lamb_step(
        0.1, 0.9, 0.999, eps, wd,
        wd_mask={"w": True, "b": False},
        trust_mask={"w": True, "b": False},
        layer_axes={"w": 0, "b": None},
        grad_clip_norm=None, mode="xla", with_aux=True,
    )
    _, _, trust = jax.jit(step)(params, grads, fused_lamb_init(params))

    want_w = _lamb_oracle_ratio(params["w"], grads["w"], eps=eps, wd=wd,
                                layer_axis=0)
    np.testing.assert_allclose(
        np.asarray(trust["w"]).reshape(-1), want_w, rtol=1e-5)
    # trust-masked leaf: applied ratio is identically 1
    np.testing.assert_allclose(np.asarray(trust["b"]).reshape(-1), 1.0)


def test_fused_step_records_applied_ratio_per_layer():
    """End-to-end through make_train_step: the recorded per-layer ratio on a
    2-layer stacked model equals the step-1 oracle computed from the step's
    own gradients."""
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, use_fused_lamb=True,
                     record_trust_ratios=True, grad_clip_norm=None)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray,
                         make_batch(cfg, np.random.default_rng(0), 2, 16))
    _, metrics = jax.jit(step_fn)(state, batch)
    rec = jax.device_get(metrics[PER_LAYER_KEY])

    # oracle from the very gradients the step consumed
    from repro.train.step import make_loss_fn
    grads = jax.grad(lambda p: make_loss_fn(model)(p, batch)[0])(state.params)
    axes = model.layer_axes()
    wd_mask, trust_mask = model.wd_mask(), model.trust_mask()

    def oracle(x, g, ax, wd_on, trust_on):
        ax = None if ax is None or ax < 0 else ax  # -1 = unstacked
        if not trust_on:
            return np.ones(np.asarray(x).shape[ax] if ax is not None else ())
        return _lamb_oracle_ratio(
            x, g, eps=tc.eps, wd=tc.weight_decay if wd_on else 0.0,
            layer_axis=ax)

    want = jax.tree.map(oracle, state.params, grads, axes, wd_mask, trust_mask)
    for got, exp in zip(jax.tree.leaves(rec["trust_ratio"]),
                        jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(got).reshape(-1),
                                   np.asarray(exp).reshape(-1), rtol=2e-4)
    # param/update norms ride along, same tree structure
    assert (jax.tree.structure(rec["param_norm"])
            == jax.tree.structure(rec["trust_ratio"]))


def test_unfused_records_match_posthoc_norms():
    """Transform-chain path: recorded ratio == phi(||w||)/||Δw|| recomputed
    from the actual parameter deltas, per layer slice."""
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                     record_trust_ratios=True)
    init_fn, step_fn = make_train_step(model, tc)
    state = init_fn(jax.random.key(0))
    batch = jax.tree.map(jnp.asarray,
                         make_batch(cfg, np.random.default_rng(0), 2, 16))
    new_state, metrics = jax.jit(step_fn)(state, batch)
    rec = jax.device_get(metrics[PER_LAYER_KEY])
    axes = model.layer_axes()

    def slice_norm(x, ax):
        x = np.asarray(x, np.float64)
        if ax is None or ax < 0:  # -1 = unstacked
            return np.sqrt((x * x).sum())
        other = tuple(i for i in range(x.ndim) if i != ax)
        return np.sqrt((x * x).sum(axis=other))

    for got_r, got_p, old, new, ax in zip(
            jax.tree.leaves(rec["trust_ratio"]),
            jax.tree.leaves(rec["param_norm"]),
            jax.tree.leaves(state.params),
            jax.tree.leaves(new_state.params),
            jax.tree.leaves(axes, is_leaf=lambda x: x is None)):
        w = slice_norm(old, ax)
        d = slice_norm(np.asarray(new) - np.asarray(old), ax)
        np.testing.assert_allclose(np.asarray(got_r).reshape(-1),
                                   np.atleast_1d(w / d), rtol=2e-4)
        np.testing.assert_allclose(np.asarray(got_p).reshape(-1),
                                   np.atleast_1d(w), rtol=2e-4)


def test_trust_recorder_histogram_and_summary():
    rec = TrustRecorder(log=EventLog.memory())
    records = {"trust_ratio": {"a": np.array([0.5, 2.0]), "b": np.array(1.0)},
               "param_norm": {"a": np.array([1.0, 1.0]), "b": np.array(3.0)},
               "update_norm": {"a": np.array([2.0, 0.5]), "b": np.array(3.0)}}
    layers = rec.record(10, records)
    assert layers["a"]["per_layer"] == [0.5, 2.0]
    assert layers["b"]["param_norm"] == [3.0]
    s = rec.summary()
    assert s["steps_recorded"] == 1
    assert s["per_leaf"]["a"] == {"min": 0.5, "max": 2.0, "mean": 1.25}
    assert sum(s["hist"]["counts"]) == 3  # every ratio landed in a bin
    ev = rec.log.events[0]
    assert ev["event"] == "trust_ratios" and ev["step"] == 10


# ---------------------------------------------------------------------------
# trainer integration: zero-overhead null sink + emitted events
# ---------------------------------------------------------------------------

def _fit_tiny(telemetry=None, steps=4, **tc_kw):
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3, **tc_kw)
    tr = Trainer(model, tc, log_every=2, log_fn=lambda s: None,
                 telemetry=telemetry)
    batch = make_batch(cfg, np.random.default_rng(0), 2, 16)
    tr.fit(itertools.repeat(batch), steps)
    return tr


TIMING_KEYS = {"wall_s"}  # legitimately differs run-to-run


def test_history_identical_with_telemetry_off_vs_on():
    h_off = _fit_tiny(telemetry=None).history
    h_on = _fit_tiny(telemetry=EventLog.memory()).history
    assert len(h_off) == len(h_on)
    for a, b in zip(h_off, h_on):
        assert set(a) == set(b)
        for k in a:
            if k not in TIMING_KEYS:
                assert a[k] == b[k], k


def test_trainer_emits_run_events():
    log = EventLog.memory()
    tr = _fit_tiny(telemetry=log, use_fused_lamb=True,
                   record_trust_ratios=True, log_trust_ratios=True)
    types = [e["event"] for e in log.events]
    assert types[0] == "run_start"
    prov = log.events[0]["provenance"]
    for k in ("git_sha", "jax_version", "device_kind", "config_hash"):
        assert k in prov, k
    assert types.count("step") == 2      # 4 steps, log_every=2
    assert types.count("span") == 2      # one per logged interval
    assert types.count("trust_ratios") == 2
    step_ev = next(e for e in log.events if e["event"] == "step")
    assert step_ev["step_time_s"] > 0
    assert "loss/total" in step_ev["metrics"]
    # per-layer records were popped out of the scalar history
    assert all(PER_LAYER_KEY not in h for h in tr.history)


def test_fit_stages_history_carries_wall_s():
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    log = EventLog.memory()
    tr = Trainer(model, tc, log_every=1, log_fn=lambda s: None, telemetry=log)
    stages = [
        core.make_stage("s1", 16, 4, 2, base_lr=1e-3, base_batch=4,
                        base_warmup_ratio=0.25),
        core.make_stage("s2", 32, 2, 2, base_lr=1e-3, base_batch=4,
                        base_warmup_ratio=0.25),
    ]
    hist = tr.fit_stages(stages)
    walls = [h["wall_s"] for h in hist]
    assert len(walls) == 4 and all(w > 0 for w in walls)
    assert walls == sorted(walls)  # one clock across stages, monotone
    assert [e["name"] for e in log.events
            if e["event"] == "stage_start"] == ["s1", "s2"]


# ---------------------------------------------------------------------------
# serve counters
# ---------------------------------------------------------------------------

def test_serve_counters_from_continuous_engine():
    from repro.serve.continuous import ContinuousEngine
    from repro.serve.scheduler import ServeRequest

    cfg = tiny_dense()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    log = EventLog.memory()
    eng = ContinuousEngine(model, params, n_slots=2, max_len=32, telemetry=log)
    reqs = [ServeRequest(prompt=np.arange(1, 5, dtype=np.int32),
                         max_new_tokens=3) for _ in range(3)]
    # a request already past its deadline on arrival must be dropped + logged
    reqs.append(ServeRequest(prompt=np.arange(1, 5, dtype=np.int32),
                             max_new_tokens=3, arrival_s=0.0, deadline_s=-1.0))
    out = eng.generate(reqs)

    sr = [e for e in log.events if e["event"] == "serve_request"]
    assert len(sr) == 4
    dropped = [e for e in sr if e["dropped"]]
    assert len(dropped) == 1 and dropped[0]["new_tokens"] == 0
    for e in sr:
        if not e["dropped"]:
            assert e["new_tokens"] == 3
            assert e["latency_s"] >= e["ttft_s"] >= 0

    stats = [e for e in log.events if e["event"] == "serve_stats"]
    assert len(stats) == 1
    st = stats[0]
    assert st["requests"] == 3 and st["dropped"] == 1
    assert st["n_slots"] == 2 and 0 < st["slot_occupancy_mean"] <= 1
    assert st["decode_steps"] > 0 and st["queue_depth_max"] >= 1
    assert sum(1 for r in out if r.dropped) == 1


# ---------------------------------------------------------------------------
# run report + regression gate
# ---------------------------------------------------------------------------

def _report_from_tiny_run():
    log = EventLog.memory()
    _fit_tiny(telemetry=log, use_fused_lamb=True, record_trust_ratios=True,
              log_trust_ratios=True)
    log.emit("run_end", status="ok")
    return RunReport.from_events(log)


def test_run_report_sections_and_io(tmp_path):
    rep = _report_from_tiny_run()
    for section in ("provenance", "train", "spans", "trust_ratios",
                    "run_end", "events"):
        assert section in rep.report, section
    assert rep.report["train"]["logged_steps"] == 2
    assert rep.report["train"]["final"]["loss/total"] > 0
    assert rep.report["trust_ratios"]["per_leaf"]
    assert sum(rep.report["trust_ratios"]["hist"]["counts"]) > 0
    p = rep.write(tmp_path / "RUN_REPORT.json")
    loaded = RunReport.load(p)
    assert loaded.report == json.loads(json.dumps(rep.report))


def test_run_report_compare_passes_within_tolerance():
    rep = _report_from_tiny_run()
    base = json.loads(json.dumps(rep.report))
    base["train"]["final"]["loss/total"] *= 1.01  # 1% off, 5% tol
    res = rep.compare(base, {
        "train.final.loss/total": 0.05,
        "train.logged_steps": 0.0,
        "spans.step.mean_s": None,        # presence only: timing drifts
        "provenance.jax_version": 0.0,    # non-numeric: exact equality
    })
    assert res.ok, res.render()
    assert "PASS" in res.render()


def test_run_report_compare_fails_on_regression_and_schema():
    rep = _report_from_tiny_run()
    base = json.loads(json.dumps(rep.report))
    base["train"]["final"]["loss/total"] *= 2.0
    base["serve"] = {"requests": 1}  # baseline section this report lacks
    res = rep.compare(base, {
        "train.final.loss/total": 0.05,
        "no.such.key": None,
    })
    assert not res.ok
    statuses = {c.key: c.status for c in res.checks}
    assert statuses["train.final.loss/total"] == "regressed"
    assert statuses["section:serve"] == "missing"
    assert statuses["no.such.key"] == "missing"
    assert "FAIL" in res.render()


def test_run_report_folds_bench_json(tmp_path):
    (tmp_path / "BENCH_demo.json").write_text(
        json.dumps({"holds": True, "provenance": {"git_sha": "abc"}}))
    log = EventLog.memory()
    log.emit("run_start", provenance=run_provenance(), mode="bench")
    log.emit("bench_result", name="demo", ok=True, rows=3)
    log.emit("run_end", status="ok")
    rep = RunReport.from_events(log, bench_dir=tmp_path)
    assert rep.report["bench"]["demo"]["ok"] is True
    assert rep.report["bench"]["demo"]["json"]["holds"] is True
