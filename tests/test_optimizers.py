"""LAMB / LARS / baselines: semantics vs the paper's Algorithms 1-2."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core, optim
from repro.kernels.ref import lamb_update_ref


def _tree(rng, scale=1.0):
    return {
        "w": jnp.asarray(rng.standard_normal((8, 16)) * scale, jnp.float32),
        "b": jnp.asarray(rng.standard_normal((16,)) * scale, jnp.float32),
    }


def test_lamb_matches_single_tensor_reference(rng):
    """core.lamb == the closed-form Algorithm-2 update (via kernels.ref)."""
    x = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    params = {"w": x}
    opt = core.lamb(0.01, weight_decay=0.01)
    state = opt.init(params)
    u, _ = opt.update({"w": g}, state, params)
    got = optim.apply_updates(params, u)["w"]
    want, _, _ = lamb_update_ref(
        x, g, jnp.zeros_like(x), jnp.zeros_like(x),
        lr=0.01, weight_decay=0.01, step=1,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_lamb_update_norm_equals_lr_times_phi(rng):
    """Algorithm 2: per-layer update norm == eta * phi(||x||)."""
    params = _tree(rng)
    g = _tree(rng)
    lr = 0.02
    opt = core.lamb(lr, weight_decay=0.01)
    u, _ = opt.update(g, opt.init(params), params)
    for k in params:
        unorm = float(jnp.linalg.norm(u[k]))
        xnorm = float(jnp.linalg.norm(params[k]))
        assert unorm == pytest.approx(lr * xnorm, rel=1e-4)


def test_lamb_gradient_scale_invariance(rng):
    """From zero moments, Adam's r (and hence LAMB) is invariant to g → c·g."""
    params = _tree(rng)
    g = _tree(rng)
    g_scaled = jax.tree.map(lambda x: 100.0 * x, g)
    opt = core.lamb(0.01, weight_decay=0.005, eps=0.0)
    u1, _ = opt.update(g, opt.init(params), params)
    u2, _ = opt.update(g_scaled, opt.init(params), params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)


def test_scan_aware_slicing_equals_unstacked(rng):
    """Stacked (L, ...) leaf + layer_axes == L separate per-layer leaves."""
    L = 3
    stacked = {"w": jnp.asarray(rng.standard_normal((L, 8, 4)), jnp.float32)}
    g_stacked = {"w": jnp.asarray(rng.standard_normal((L, 8, 4)), jnp.float32)}
    opt_s = core.lamb(0.01, weight_decay=0.01, layer_axes={"w": 0})
    u_s, _ = opt_s.update(g_stacked, opt_s.init(stacked), stacked)

    per_layer = {f"w{i}": stacked["w"][i] for i in range(L)}
    g_per = {f"w{i}": g_stacked["w"][i] for i in range(L)}
    opt_u = core.lamb(0.01, weight_decay=0.01)
    u_u, _ = opt_u.update(g_per, opt_u.init(per_layer), per_layer)

    for i in range(L):
        np.testing.assert_allclose(
            np.asarray(u_s["w"][i]), np.asarray(u_u[f"w{i}"]), rtol=1e-5, atol=1e-7
        )


def test_trust_mask_excludes_leaves(rng):
    params = _tree(rng)
    g = _tree(rng)
    opt = core.lamb(0.01, weight_decay=0.0, trust_mask={"w": True, "b": False})
    u, _ = opt.update(g, opt.init(params), params)
    # masked leaf: plain adam*lr (unit-free), i.e. NOT rescaled to lr*||x||
    assert float(jnp.linalg.norm(u["w"])) == pytest.approx(
        0.01 * float(jnp.linalg.norm(params["w"])), rel=1e-4
    )
    assert float(jnp.linalg.norm(u["b"])) != pytest.approx(
        0.01 * float(jnp.linalg.norm(params["b"])), rel=1e-2
    )


# ---------------------------------------------------------------------------
# LANS (Zheng et al., the 54-minute paper): block-normalized gradients into
# the Adam moments + the Nesterov two-term update, each term trust-rescaled
# ---------------------------------------------------------------------------

def _lans_numpy_oracle(x, g, m, v, *, lr, b1=0.9, b2=0.999, eps=1e-6,
                       wd=0.01, step=1):
    """Pure-numpy LANS step on one tensor (float64 arithmetic)."""
    x, g, m, v = (np.asarray(a, np.float64) for a in (x, g, m, v))
    gn = np.linalg.norm(g)
    g_t = g / gn if gn > 0 else g
    m_new = b1 * m + (1 - b1) * g_t
    v_new = b2 * v + (1 - b2) * g_t * g_t
    denom = np.sqrt(v_new / (1 - b2**step)) + eps
    d_m = m_new / (1 - b1**step) / denom + wd * x
    d_g = g_t / denom + wd * x

    def ratio(u):
        un, xn = np.linalg.norm(u), np.linalg.norm(x)
        return xn / un if (xn > 0 and un > 0) else 1.0

    x_new = x - lr * (b1 * ratio(d_m) * d_m + (1 - b1) * ratio(d_g) * d_g)
    return x_new, m_new, v_new


def test_lans_matches_numpy_oracle(rng):
    """core.lans step-equivalence vs the float64 numpy oracle, multi-step
    (moments accumulate, bias correction advances)."""
    x = rng.standard_normal((16, 8)).astype(np.float32)
    params = {"w": jnp.asarray(x)}
    opt = core.lans(0.01, weight_decay=0.01)
    state = opt.init(params)
    m = np.zeros_like(x, np.float64)
    v = np.zeros_like(x, np.float64)
    for step in range(1, 5):
        g = rng.standard_normal((16, 8)).astype(np.float32)
        u, state = opt.update({"w": jnp.asarray(g)}, state, params)
        params = optim.apply_updates(params, u)
        x, m, v = _lans_numpy_oracle(x, g, m, v, lr=0.01, step=step)
        np.testing.assert_allclose(
            np.asarray(params["w"]), x, rtol=1e-4, atol=1e-6
        )


def test_lans_matches_fused_xla_reference(rng):
    """Unfused transform chain ≡ the single fused-XLA expression
    (kernels.ref.lans_update_ref), jitted, over several steps."""
    from repro.kernels.ref import lans_update_ref

    x = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
    params = {"w": x}
    opt = core.lans(0.02, weight_decay=0.01)
    state = opt.init(params)
    fused = jax.jit(
        lambda x, g, m, v, step: lans_update_ref(
            x, g, m, v, lr=0.02, weight_decay=0.01, step=step
        )
    )
    m, v = jnp.zeros_like(x), jnp.zeros_like(x)
    for step in range(1, 4):
        g = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
        u, state = opt.update({"w": g}, state, params)
        params = optim.apply_updates(params, u)
        x, m, v = fused(x, g, m, v, step)
        np.testing.assert_allclose(
            np.asarray(params["w"]), np.asarray(x), rtol=1e-5, atol=1e-6
        )


def test_lans_gradient_scale_fully_invariant(rng):
    """Stronger than LAMB: the block normalization makes EVERY step exactly
    invariant to g → c·g (c > 0), even with accumulated moments — the
    property that lets LANS drop gradient-clipping sensitivity."""
    params = _tree(rng)
    opt = core.lans(0.01, weight_decay=0.005)
    s1, s2 = opt.init(params), opt.init(params)
    p1 = p2 = params
    for t in range(3):
        g = _tree(np.random.default_rng(t))
        g_scaled = jax.tree.map(lambda x: 37.5 * x, g)
        u1, s1 = opt.update(g, s1, p1)
        p1 = optim.apply_updates(p1, u1)
        u2, s2 = opt.update(g_scaled, s2, p2)
        p2 = optim.apply_updates(p2, u2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_lans_scan_aware_slicing_equals_unstacked(rng):
    """Stacked (L, ...) leaf + layer_axes == L separate per-layer leaves:
    both the gradient normalization and the two trust ratios must be
    computed per layer slice."""
    L = 3
    stacked = {"w": jnp.asarray(rng.standard_normal((L, 8, 4)), jnp.float32)}
    g_stacked = {"w": jnp.asarray(rng.standard_normal((L, 8, 4)), jnp.float32)}
    opt_s = core.lans(0.01, weight_decay=0.01, layer_axes={"w": 0})
    u_s, _ = opt_s.update(g_stacked, opt_s.init(stacked), stacked)

    per_layer = {f"w{i}": stacked["w"][i] for i in range(L)}
    g_per = {f"w{i}": g_stacked["w"][i] for i in range(L)}
    opt_u = core.lans(0.01, weight_decay=0.01)
    u_u, _ = opt_u.update(g_per, opt_u.init(per_layer), per_layer)

    for i in range(L):
        np.testing.assert_allclose(
            np.asarray(u_s["w"][i]), np.asarray(u_u[f"w{i}"]),
            rtol=1e-5, atol=1e-7,
        )


def test_lans_trust_mask_excludes_leaves(rng):
    """Masked-out leaves skip both trust rescales but keep the normalized
    two-term direction (the LAMB exclusion convention)."""
    params = _tree(rng)
    g = _tree(rng)
    opt = core.lans(0.01, weight_decay=0.0,
                    trust_mask={"w": True, "b": False})
    u, _ = opt.update(g, opt.init(params), params)
    ref = core.lans(0.01, weight_decay=0.0)
    u_ref, _ = ref.update(g, ref.init(params), params)
    # trusted leaf identical to the all-trusted run; masked leaf differs
    np.testing.assert_allclose(np.asarray(u["w"]), np.asarray(u_ref["w"]))
    assert not np.allclose(np.asarray(u["b"]), np.asarray(u_ref["b"]))


def test_lans_normalize_grads_blockwise(rng):
    """core.normalize_grads: unit norm per leaf (per slice when stacked);
    zero blocks pass through."""
    g = {
        "w": jnp.asarray(rng.standard_normal((3, 4, 5)), jnp.float32),
        "z": jnp.zeros((4,), jnp.float32),
    }
    out = core.normalize_grads(g, layer_axes={"w": 0, "z": None})
    for i in range(3):
        assert float(jnp.linalg.norm(out["w"][i])) == pytest.approx(1.0, rel=1e-5)
    np.testing.assert_array_equal(np.asarray(out["z"]), 0.0)


def test_lans_records_per_layer_trust_ratios(rng):
    """A LANS train step with record_trust_ratios=True returns the
    per-layer telemetry records pytree under metrics['telemetry/per_layer']
    with one ratio per scanned layer slice."""
    from repro.configs.base import TrainConfig
    from repro.models import build_model
    from repro.telemetry.trust import PER_LAYER_KEY
    from repro.train.step import make_train_step
    from tests.conftest import tiny_dense

    model = build_model(tiny_dense())
    tc = TrainConfig(optimizer="lans", learning_rate=1e-3,
                     record_trust_ratios=True)
    init_fn, step_fn = make_train_step(model, tc)
    state = jax.jit(init_fn)(jax.random.key(0))
    from repro.data import DataPipeline

    batch = next(DataPipeline(tiny_dense(), 4, 16, seed=0))
    state, metrics = jax.jit(step_fn)(state, batch)
    assert PER_LAYER_KEY in metrics
    rec = metrics[PER_LAYER_KEY]
    ratios = rec["trust_ratio"]
    # stacked attention leaves carry one ratio per layer
    n_layers = tiny_dense().n_layers
    stacked = jax.tree.leaves(ratios["blocks"])
    assert any(x.shape and x.shape[0] == n_layers for x in stacked)
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(ratios))


def test_lars_momentum_form(rng):
    """Algorithm 1: m = b1*m + (1-b1)(g + wd*x); update direction ∝ m."""
    params = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 2.0)}
    wd, b1, lr = 0.1, 0.9, 0.5
    opt = core.lars(lr, momentum=b1, weight_decay=wd)
    u, _ = opt.update(g, opt.init(params), params)
    m = (1 - b1) * (2.0 + wd * 1.0)  # scalar, all entries equal
    # update = -lr * ||x||/||m|| * m  (phi = identity)
    expect = -lr * 4.0 / (m * 4.0) * m  # norms over 16 entries: 4*|val|
    np.testing.assert_allclose(np.asarray(u["w"]), expect, rtol=1e-5)


def test_phi_bounds_clip(rng):
    params = {"w": jnp.ones((2, 2)) * 100.0}  # ||x|| = 200
    g = {"w": jnp.ones((2, 2))}
    opt = core.lamb(1.0, weight_decay=0.0, phi_bounds=(0.0, 1.5))
    u, _ = opt.update(g, opt.init(params), params)
    assert float(jnp.linalg.norm(u["w"])) == pytest.approx(1.5, rel=1e-4)


def test_zero_param_norm_falls_back_to_ratio_one():
    params = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.ones((4, 4))}
    opt = core.lamb(0.01, weight_decay=0.0)
    u, _ = opt.update(g, opt.init(params), params)
    assert float(jnp.linalg.norm(u["w"])) > 0  # params still move


def test_bias_correction_off_app_e(rng):
    """App. E: removing adam-correction only rescales early steps."""
    params = _tree(rng)
    g = _tree(rng)
    on = core.lamb(0.01, bias_correction=True)
    off = core.lamb(0.01, bias_correction=False)
    u_on, _ = on.update(g, on.init(params), params)
    u_off, _ = off.update(g, off.init(params), params)
    # layerwise normalization makes step-1 updates identical in *direction*
    for a, b in zip(jax.tree.leaves(u_on), jax.tree.leaves(u_off)):
        cos = jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b))
        assert float(cos) == pytest.approx(1.0, abs=1e-4)


def test_nlamb_nnlamb_step(rng):
    params = _tree(rng)
    g = _tree(rng)
    for f in (core.nlamb, core.nnlamb):
        opt = f(0.01)
        u, s = opt.update(g, opt.init(params), params)
        assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(u))


@pytest.mark.parametrize("name", ["adam", "adamw", "adagrad", "momentum", "sgd"])
def test_baselines_step(name, rng):
    params = _tree(rng)
    g = _tree(rng)
    opt = getattr(optim, name)(0.01)
    u, s = opt.update(g, opt.init(params), params)
    p2 = optim.apply_updates(params, u)
    assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(p2))


def test_grad_clip(rng):
    params = _tree(rng)
    g = jax.tree.map(lambda x: 1e4 * x, _tree(rng))
    opt = optim.chain(optim.clip_by_global_norm(1.0), optim.scale_by_learning_rate(1.0))
    u, _ = opt.update(g, opt.init(params), params)
    total = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(u))))
    assert total == pytest.approx(1.0, rel=1e-4)


def test_bf16_moments_close_to_fp32(rng):
    """C1 §Perf knob: bf16 m/v track fp32 moments to bf16 tolerance."""
    params = _tree(rng)
    o32 = core.lamb(0.01, weight_decay=0.01)
    o16 = core.lamb(0.01, weight_decay=0.01, moment_dtype="bfloat16")
    s32, s16 = o32.init(params), o16.init(params)
    p32 = p16 = params
    for t in range(5):
        g = _tree(np.random.default_rng(t))
        u32, s32 = o32.update(g, s32, p32)
        p32 = optim.apply_updates(p32, u32)
        u16, s16 = o16.update(g, s16, p16)
        p16 = optim.apply_updates(p16, u16)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p16)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.05, atol=5e-3)
    # the moments really are half-width
    assert jax.tree.leaves(s16[0].mu)[0].dtype == jnp.bfloat16


@pytest.mark.parametrize("ord_", ["l1", "l2", "linf"])
def test_norm_choice_ablation_app_f(ord_, rng):
    """App. F: LAMB runs with L1/L2/L∞ trust-ratio norms; update direction
    is identical (only the per-layer scale changes)."""
    params = _tree(rng)
    g = _tree(rng)
    opt = core.lamb(0.01, weight_decay=0.01, norm_ord=ord_)
    u, _ = opt.update(g, opt.init(params), params)
    ref = core.lamb(0.01, weight_decay=0.01)
    u2, _ = ref.update(g, ref.init(params), params)
    for a, b in zip(jax.tree.leaves(u), jax.tree.leaves(u2)):
        a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
        cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos == pytest.approx(1.0, abs=1e-5)  # same direction
        assert np.isfinite(a).all()
