"""Large-batch training path: accumulation equivalence, mixed precision,
fused-LAMB parity, and the effective-batch telemetry."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core, optim
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import make_batch
from repro.kernels import FusedLambState, fused_lamb
from repro.models import build_model
from repro.train import Trainer
from repro.train.step import make_train_step
from tests.conftest import tiny_dense

RNG = np.random.default_rng(7)


def _params_stacked():
    return {
        "stack": {"w": jnp.asarray(RNG.standard_normal((3, 24, 8)), jnp.float32)},
        "emb": jnp.asarray(RNG.standard_normal((64, 8)), jnp.float32),
        "norm": jnp.ones((8,), jnp.float32),
    }


def _grads_like(params):
    return jax.tree.map(
        lambda x: jnp.asarray(RNG.standard_normal(x.shape), jnp.float32), params
    )


# ---------------------------------------------------------------------------
# accumulation equivalence
# ---------------------------------------------------------------------------

def test_accum_equivalent_to_full_batch_lamb(key):
    """k microbatches == one k×batch LAMB step (uniform supervision)."""
    cfg = tiny_dense(activation_dtype="float32")
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 8, 16)
    )
    tc_full = TrainConfig(optimizer="lamb", grad_clip_norm=None)
    tc_acc = TrainConfig(optimizer="lamb", grad_clip_norm=None, accum_steps=4)
    i1, s1 = make_train_step(model, tc_full)
    i2, s2 = make_train_step(model, tc_acc)
    st1, m1 = jax.jit(s1)(i1(key), batch)
    st2, m2 = jax.jit(s2)(i2(key), batch)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
    assert float(m1["loss/total"]) == pytest.approx(float(m2["loss/total"]), rel=1e-4)


def test_accum_equivalent_under_masking(key):
    """Token-weighted accumulation: equivalence holds when microbatch slices
    carry *unequal* supervised-token counts (MLM masking)."""
    cfg = get_config("bert-large").replace(
        name="bert-mini", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, activation_dtype="float32",
    )
    model = build_model(cfg)
    b = make_batch(cfg, np.random.default_rng(0), 8, 32)
    counts = [(b["labels"][i * 2:(i + 1) * 2] >= 0).sum() for i in range(4)]
    assert len(set(int(c) for c in counts)) > 1, "slices should be unequal"
    batch = jax.tree.map(jnp.asarray, b)
    tc_full = TrainConfig(optimizer="lamb", grad_clip_norm=None)
    tc_acc = TrainConfig(optimizer="lamb", grad_clip_norm=None, accum_steps=4)
    i1, s1 = make_train_step(model, tc_full)
    i2, s2 = make_train_step(model, tc_acc)
    st1, m1 = jax.jit(s1)(i1(key), batch)
    st2, m2 = jax.jit(s2)(i2(key), batch)
    for a, c in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4, atol=2e-5)
    # the accumulated step reports the *total* supervised tokens of the
    # global batch, equal to the full-batch count
    assert float(m2["tokens/supervised"]) == pytest.approx(
        float(m1["tokens/supervised"])
    )


def test_indivisible_accum_raises(key):
    """batch % accum_steps != 0 must fail loudly, not drop remainder rows."""
    cfg = tiny_dense()
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16)
    )
    tc = TrainConfig(optimizer="lamb", accum_steps=3)
    init_fn, step_fn = make_train_step(model, tc)
    with pytest.raises(ValueError, match="not divisible"):
        jax.jit(step_fn)(init_fn(key), batch)


def test_legacy_microbatch_alias(key):
    """tc.microbatch (PR-0 API) still drives accumulation via grad_accum_steps."""
    assert TrainConfig(microbatch=4).grad_accum_steps == 4
    assert TrainConfig(accum_steps=2).grad_accum_steps == 2
    assert TrainConfig().grad_accum_steps == 1


# ---------------------------------------------------------------------------
# mixed precision
# ---------------------------------------------------------------------------

def test_bf16_step_keeps_fp32_masters(key):
    cfg = tiny_dense()
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16)
    )
    tc = TrainConfig(optimizer="lamb", precision="bf16", accum_steps=2)
    init_fn, step_fn = make_train_step(model, tc)
    st, m = jax.jit(step_fn)(init_fn(key), batch)
    assert all(
        x.dtype == jnp.float32
        for x in jax.tree.leaves(st.params)
        if jnp.issubdtype(x.dtype, jnp.floating)
    )
    assert np.isfinite(float(m["loss/total"]))
    assert float(m["grad_norm"]) > 0


def test_bf16_trust_ratios_match_fp32_bounds(key):
    """bf16 compute must not blow up the trust ratio: per-step summaries stay
    within a small factor of the fp32 run (norm reductions are fp32)."""
    cfg = tiny_dense(activation_dtype="float32")
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16)
    )

    def summaries(precision):
        tc = TrainConfig(
            optimizer="lamb", precision=precision, log_trust_ratios=True
        )
        init_fn, step_fn = make_train_step(model, tc)
        _, m = jax.jit(step_fn)(init_fn(key), batch)
        return {k: float(v) for k, v in m.items() if k.startswith("trust_ratio/")}

    t32, t16 = summaries("fp32"), summaries("bf16")
    assert t16["trust_ratio/min"] > 0
    for k in t32:
        assert t16[k] == pytest.approx(t32[k], rel=0.15), (k, t32[k], t16[k])


def test_unknown_precision_raises():
    with pytest.raises(ValueError):
        TrainConfig(precision="fp8").compute_dtype


# ---------------------------------------------------------------------------
# fused LAMB in the train step
# ---------------------------------------------------------------------------

def test_fused_xla_transform_matches_core_lamb_stacked_and_unstacked():
    """XLA-fallback fused backend == unfused chain on stacked + unstacked
    leaves (the Pallas interpret backend is covered in test_kernels)."""
    params = _params_stacked()
    la = {"stack": {"w": 0}, "emb": -1, "norm": -1}
    tm = {"stack": {"w": True}, "emb": True, "norm": False}
    wm = {"stack": {"w": True}, "emb": True, "norm": False}
    sched = core.warmup_poly_decay(0.01, 50, 5)
    o1 = core.lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                   wd_mask=wm)
    o2 = fused_lamb(sched, weight_decay=0.01, layer_axes=la, trust_mask=tm,
                    wd_mask=wm, backend="xla")
    s1, s2 = o1.init(params), o2.init(params)
    p1 = p2 = params
    for _ in range(4):
        g = _grads_like(params)
        u1, s1 = o1.update(g, s1, p1)
        p1 = optim.apply_updates(p1, u1)
        u2, s2 = o2.update(g, s2, p2)
        p2 = optim.apply_updates(p2, u2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-6)


def test_fused_transform_grad_clip_matches_chain():
    params = _params_stacked()
    g = jax.tree.map(lambda x: 50.0 * x, _grads_like(params))
    o1 = core.lamb(0.01, weight_decay=0.01, grad_clip_norm=1.0)
    o2 = fused_lamb(0.01, weight_decay=0.01, grad_clip_norm=1.0, backend="xla")
    u1, _ = o1.update(g, o1.init(params), params)
    u2, _ = o2.update(g, o2.init(params), params)
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-6)


def test_fused_train_step_parity(key):
    """The direct fused-apply train step tracks the unfused step for several
    iterations on a real (scanned-stack) model."""
    cfg = tiny_dense(activation_dtype="float32")
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16)
    )
    tc_u = TrainConfig(optimizer="lamb")
    tc_f = TrainConfig(optimizer="lamb", use_fused_lamb=True, fused_backend="xla")
    iu, su = make_train_step(model, tc_u)
    iff, sf = make_train_step(model, tc_f)
    stu, stf = iu(key), iff(key)
    assert isinstance(stf.opt_state, FusedLambState)
    su_j, sf_j = jax.jit(su), jax.jit(sf)
    for _ in range(3):
        stu, _ = su_j(stu, batch)
        stf, _ = sf_j(stf, batch)
    for a, b in zip(jax.tree.leaves(stu.params), jax.tree.leaves(stf.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_fused_rejects_unsupported_options(key):
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", use_fused_lamb=True, bias_correction=False)
    with pytest.raises(ValueError):
        make_train_step(model, tc)


def test_fused_stage_rewarmup_resets_sched_count_only():
    """fit_stages with fused LAMB: schedule counter restarts, moments age on."""
    cfg = tiny_dense()
    model = build_model(cfg)
    tc = TrainConfig(optimizer="lamb", use_fused_lamb=True, fused_backend="xla")
    stages = [
        core.make_stage("s1", 16, 4, 3, base_lr=1e-3, base_batch=4,
                        base_warmup_ratio=0.25),
        core.make_stage("s2", 32, 2, 3, base_lr=1e-3, base_batch=4,
                        base_warmup_ratio=0.25),
    ]
    tr = Trainer(model, tc, log_every=1, log_fn=lambda s: None)
    tr.fit_stages(stages)
    st: FusedLambState = tr.state.opt_state
    assert int(tr.state.step) == 6
    assert int(st.count) == 6          # moments aged across both stages
    assert int(st.sched_count) == 3    # schedule restarted for stage 2


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_examples_seen_uses_effective_global_batch():
    """history examples_seen is microbatch × accum — identical across
    accumulation settings for the same global batch."""
    cfg = tiny_dense()
    model = build_model(cfg)
    batch = make_batch(cfg, np.random.default_rng(0), 8, 16)

    def run(tc):
        tr = Trainer(model, tc, log_every=1, log_fn=lambda s: None)
        tr.fit(itertools.repeat(batch), 3)
        return tr

    tr1 = run(TrainConfig(optimizer="lamb"))
    tr2 = run(TrainConfig(optimizer="lamb", accum_steps=4))
    assert tr1.examples_seen == tr2.examples_seen == 24
    assert tr1.history[-1]["examples_seen"] == 24
    assert tr2.history[-1]["examples_seen"] == 24


def test_step_metrics_include_norm_telemetry(key):
    cfg = tiny_dense()
    model = build_model(cfg)
    batch = jax.tree.map(
        jnp.asarray, make_batch(cfg, np.random.default_rng(0), 4, 16)
    )
    tc = TrainConfig(optimizer="lamb", log_trust_ratios=True, use_fused_lamb=True)
    init_fn, step_fn = make_train_step(model, tc)
    _, m = jax.jit(step_fn)(init_fn(key), batch)
    for k in ("grad_norm", "update_norm", "trust_ratio/mean", "tokens/supervised"):
        assert k in m, k
        assert np.isfinite(float(m[k]))
