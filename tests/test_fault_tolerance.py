"""Numerical fault tolerance: skip-step guard, spike watchdog, preemption.

Property tests (hypothesis) pin the spike detector's two-sided contract —
no false trips on stationary noisy loss, guaranteed trips on genuine
spikes — and unit/integration tests drive the in-jit guard, the rollback
supervisor and the SIGTERM preemption path end-to-end on a single device
(the sharded variants live in tests/sharded_harness.py).
"""
import math
import os
import signal

import pytest

try:  # property tests run under hypothesis when present; the deterministic
    import hypothesis  # grid versions below always run either way
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed (see requirements-dev.txt)",
)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import (  # noqa: E402
    checkpoint_step,
    discard_checkpoints_after,
    latest_checkpoint,
    save_checkpoint,
)
from repro.configs.base import TrainConfig  # noqa: E402
from repro.data import DataPipeline  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.telemetry import EventLog  # noqa: E402
from repro.train import (  # noqa: E402
    DivergenceError,
    FaultInjector,
    FaultSpec,
    PreemptionHandler,
    SpikeDetector,
    SupervisorConfig,
    Trainer,
    TrainingSupervisor,
    tree_all_finite,
)
from repro.train.faults import FAULT_PREFIX, split_faults  # noqa: E402
from repro.train.step import GUARD_KEY, make_train_step  # noqa: E402
from tests.conftest import tiny_dense  # noqa: E402

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "repro_ft", deadline=None, max_examples=25, derandomize=True,
        suppress_health_check=[hypothesis.HealthCheck.too_slow],
    )
    hypothesis.settings.load_profile("repro_ft")

BATCH, SEQ = 8, 16


def _fresh_detector():
    return SpikeDetector(window=32, zmax=8.0, min_history=8, min_rel_jump=0.5)


# ---------------------------------------------------------------------------
# spike detector properties
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @hypothesis.given(
        base=st.floats(0.5, 10.0, allow_nan=False, allow_subnormal=False),
        noise=st.lists(
            st.floats(-0.1, 0.1, allow_nan=False, allow_subnormal=False),
            min_size=20, max_size=80,
        ),
    )
    def test_detector_never_trips_on_stationary_noise(base, noise):
        """Loss wobbling within ±10% of a stationary level must never trip:
        the relative-jump gate requires a spike of at least min_rel_jump
        relative to the window median."""
        det = _fresh_detector()
        for eps in noise:
            assert not det.observe(base * (1.0 + eps))

    @needs_hypothesis
    @hypothesis.given(
        base=st.floats(0.5, 10.0, allow_nan=False, allow_subnormal=False),
        noise=st.lists(
            st.floats(-0.05, 0.05, allow_nan=False, allow_subnormal=False),
            min_size=12, max_size=40,
        ),
        factor=st.floats(10.0, 1e4, allow_nan=False, allow_subnormal=False),
    )
    def test_detector_always_trips_on_spike(base, noise, factor):
        """A >=10x excursion after a settled window must always trip (both
        the z-score and the relative-jump gate clear by construction)."""
        det = _fresh_detector()
        for eps in noise:
            det.observe(base * (1.0 + eps))
        assert det.observe(base * factor)


@pytest.mark.parametrize("base", [0.5, 1.0, 2.7, 10.0])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_detector_never_trips_on_stationary_noise_grid(base, seed):
    """Deterministic version of the no-false-trip property (always runs)."""
    rng = np.random.default_rng(seed)
    det = _fresh_detector()
    for eps in rng.uniform(-0.1, 0.1, size=60):
        assert not det.observe(base * (1.0 + float(eps)))


@pytest.mark.parametrize("base", [0.5, 1.0, 2.7, 10.0])
@pytest.mark.parametrize("factor", [10.0, 100.0, 1e4])
def test_detector_always_trips_on_spike_grid(base, factor):
    """Deterministic version of the guaranteed-trip property (always runs)."""
    rng = np.random.default_rng(0)
    det = _fresh_detector()
    for eps in rng.uniform(-0.05, 0.05, size=20):
        det.observe(base * (1.0 + float(eps)))
    assert det.observe(base * factor)


@pytest.mark.parametrize("base", [0.5, 2.7, 10.0])
@pytest.mark.parametrize(
    "bad", [float("nan"), float("inf"), float("-inf")]
)
def test_detector_trips_on_nonfinite_loss(base, bad):
    det = _fresh_detector()
    for _ in range(12):
        det.observe(base)
    assert det.observe(bad)


def test_detector_spike_not_fed_into_window():
    """A tripping observation must not poison the baseline: the next equal
    spike still trips (otherwise one spike would raise the median and mask
    its successors)."""
    det = _fresh_detector()
    for _ in range(12):
        det.observe(1.0)
    assert det.observe(50.0)
    assert det.observe(50.0)


def test_detector_constant_window_zero_mad():
    """An exactly constant window (MAD=0) must not trip on a microscopic
    wobble — the relative-jump AND-gate, not the epsilon floor, holds."""
    det = _fresh_detector()
    for _ in range(12):
        det.observe(2.0)
    assert not det.observe(2.0 + 1e-6)
    assert det.observe(50.0)


# ---------------------------------------------------------------------------
# in-jit non-finite guard: skip-step state identity
# ---------------------------------------------------------------------------

def _one_step(tc, batch):
    model = build_model(tiny_dense())
    init_fn, step_fn = make_train_step(model, tc)
    state = jax.jit(init_fn)(jax.random.key(0))
    return model, jax.jit(step_fn), state, batch


def _poisoned(batch, kind="grad_nan"):
    inj = FaultInjector([FaultSpec(kind, at=0)])
    return inj.stamp(dict(batch), 0)


VARIANTS = {
    "unfused": dict(),
    "fused": dict(use_fused_lamb=True),
    "accum2": dict(accum_steps=2),
    "bf16": dict(precision="bf16"),
}


@pytest.mark.parametrize("variant", sorted(VARIANTS))
@pytest.mark.parametrize("kind", ["grad_nan", "grad_inf"])
def test_skip_step_leaves_state_bit_identical(variant, kind):
    """A poisoned step with the guard on must be a true no-op: every param
    and optimizer leaf bitwise unchanged, step not advanced, skipped+1."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                     skip_nonfinite=True, **VARIANTS[variant])
    data = DataPipeline(tiny_dense(), BATCH, SEQ, seed=0)
    model, step, state, batch = _one_step(tc, next(data))
    before = jax.tree.map(np.asarray, state)

    new_state, metrics = step(state, _poisoned(batch, kind))

    assert float(metrics[GUARD_KEY]) == 1.0
    assert int(new_state.step) == 0
    assert int(new_state.skipped) == 1
    for p, b in zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(before.params)):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(b))
    for p, b in zip(jax.tree.leaves(new_state.opt_state),
                    jax.tree.leaves(before.opt_state)):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(b))


@pytest.mark.parametrize("variant", ["unfused", "fused"])
def test_clean_step_advances_normally_with_guard(variant):
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                     skip_nonfinite=True, **VARIANTS[variant])
    data = DataPipeline(tiny_dense(), BATCH, SEQ, seed=0)
    _, step, state, batch = _one_step(tc, next(data))
    new_state, metrics = step(state, batch)
    assert float(metrics[GUARD_KEY]) == 0.0
    assert int(new_state.step) == 1
    assert int(new_state.skipped) == 0


def test_guard_off_propagates_nan():
    """Contrast: without the guard a poisoned gradient corrupts params —
    the failure mode the guard exists to stop."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    data = DataPipeline(tiny_dense(), BATCH, SEQ, seed=0)
    _, step, state, batch = _one_step(tc, next(data))
    new_state, _ = step(state, _poisoned(batch, "grad_nan"))
    finite = bool(tree_all_finite(new_state.params))
    assert not finite


def test_nan_skip_matches_dropped_ordinal_run():
    """Single-device version of the harness gate: injected-and-skipped ==
    clean run whose stream omits the poisoned batch, bitwise."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                     skip_nonfinite=True)
    cfg = tiny_dense()
    model = build_model(cfg)
    inj = FaultInjector([FaultSpec("grad_nan", at=1)])

    tr = Trainer(model, tc, log_every=1000, log_fn=lambda s: None)
    tr.fit(inj.wrap(DataPipeline(cfg, BATCH, SEQ, seed=0)), 4)

    def drop(data, k):
        for i, b in enumerate(data):
            if i != k:
                yield b

    clean = Trainer(model, tc, log_every=1000, log_fn=lambda s: None)
    clean.fit(drop(DataPipeline(cfg, BATCH, SEQ, seed=0), 1), 3)

    assert int(tr.state.skipped) == 1
    assert int(tr.state.step) == int(clean.state.step) == 3
    for a, b in zip(jax.tree.leaves(tr.state.params),
                    jax.tree.leaves(clean.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_channels_do_not_leak_into_loss():
    """The fault/* channels must be popped before the loss ever sees the
    batch: a stamped-but-inactive batch trains bit-identically to a clean
    one."""
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3,
                     skip_nonfinite=True)
    data = DataPipeline(tiny_dense(), BATCH, SEQ, seed=0)
    _, step, state, batch = _one_step(tc, next(data))
    inj = FaultInjector([FaultSpec("grad_nan", at=99)])  # never fires here
    s1, m1 = step(state, inj.stamp(dict(batch), 0))

    _, step2, state2, _ = _one_step(tc, batch)
    s2, m2 = step2(state2, batch)
    assert float(m1["loss/total"]) == float(m2["loss/total"])
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault injector determinism
# ---------------------------------------------------------------------------

def test_injector_is_deterministic_and_once():
    spec = [FaultSpec("grad_nan", at=2), FaultSpec("grad_inf", at=-1,
                                                   once=False)]
    batches = [{"x": np.zeros((4,), np.float32)} for _ in range(4)]

    inj = FaultInjector(spec)
    first = [inj.stamp(dict(b), i) for i, b in enumerate(batches)]
    nan_chan = [float(b[FAULT_PREFIX + "grad_nan"][0]) for b in first]
    inf_chan = [float(b[FAULT_PREFIX + "grad_inf"][0]) for b in first]
    assert nan_chan == [0.0, 0.0, 1.0, 0.0]
    assert inf_chan == [1.0, 1.0, 1.0, 1.0]  # at<0 fires every batch

    # once=True survives a rollback's stream rebuild: replaying ordinal 2
    # through the SAME injector must not re-fire
    replay = inj.stamp(dict(batches[2]), 2)
    assert float(replay[FAULT_PREFIX + "grad_nan"][0]) == 0.0

    # a fresh injector with the same specs reproduces the same stamps
    inj2 = FaultInjector(spec)
    again = [inj2.stamp(dict(b), i) for i, b in enumerate(batches)]
    assert [float(b[FAULT_PREFIX + "grad_nan"][0]) for b in again] == nan_chan


def test_injector_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("grad_zero", at=0)


def test_split_faults_passthrough():
    clean = {"tokens": np.zeros((2, 4), np.int32)}
    b, f = split_faults(clean)
    assert b is clean and f == {}
    stamped = dict(clean)
    stamped[FAULT_PREFIX + "grad_nan"] = np.ones((2,), np.float32)
    b, f = split_faults(stamped)
    assert set(b) == {"tokens"} and set(f) == {FAULT_PREFIX + "grad_nan"}


# ---------------------------------------------------------------------------
# supervisor semantics
# ---------------------------------------------------------------------------

def test_supervisor_validates_checkpoints_lazily():
    """A healthy loss at step s validates step s-1 (the loss was computed
    on pre-update params) — never the step whose update it preceded."""
    sup = TrainingSupervisor(SupervisorConfig(min_history=2))
    assert sup.last_good == -1
    assert sup.observe(1, 1.0, 0) is None
    assert sup.last_good == 0
    assert sup.observe(5, 1.0, 0) is None
    assert sup.last_good == 4


def test_supervisor_trips_on_nonfinite_loss():
    sup = TrainingSupervisor(SupervisorConfig())
    assert sup.observe(1, float("nan"), 0) == "nonfinite_loss"


def test_supervisor_consecutive_skip_budget():
    sup = TrainingSupervisor(SupervisorConfig(skip_budget=3))
    assert sup.observe(1, 1.0, 1) is None
    assert sup.observe(1, 1.0, 2) is None
    assert sup.observe(1, 1.0, 3) == "nonfinite_budget"
    # a healthy step resets the streak
    sup2 = TrainingSupervisor(SupervisorConfig(skip_budget=3))
    sup2.observe(1, 1.0, 1)
    sup2.observe(1, 1.0, 2)
    sup2.observe(2, 1.0, 2)  # no new skip
    assert sup2.observe(2, 1.0, 3) is None


def test_supervisor_rollback_budget_raises():
    sup = TrainingSupervisor(SupervisorConfig(max_rollbacks=2))
    sup.note_rollback("loss_spike")
    sup.note_rollback("loss_spike")
    with pytest.raises(DivergenceError) as ei:
        sup.note_rollback("loss_spike")
    assert ei.value.diagnostics["rollbacks"] == 3


def test_trainer_rolls_back_on_spike(tmp_path):
    cfg = tiny_dense()
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    inj = FaultInjector([FaultSpec("loss_spike", at=5, scale=100.0)])

    def make_data():
        return inj.wrap(DataPipeline(cfg, BATCH, SEQ, seed=0))

    log = EventLog.memory()
    tr = Trainer(build_model(cfg), tc, checkpoint_dir=str(tmp_path),
                 checkpoint_every=2,
                 supervisor=SupervisorConfig(spike_window=8, min_history=3),
                 telemetry=log, log_every=1, log_fn=lambda s: None)
    hist = tr.fit(make_data(), 10, data_factory=make_data)

    rollbacks = [e for e in log.events if e["event"] == "rollback"]
    assert len(rollbacks) == 1
    rb = rollbacks[0]
    assert rb["reason"] == "loss_spike"
    assert rb["step"] < rb["from_step"]
    # every batch is either trained or explicitly dropped by the rollback
    assert int(tr.state.step) == 10 - rb["batches_dropped"]
    assert math.isfinite(hist[-1]["loss/total"])
    end = [e for e in log.events if e["event"] == "run_end"][-1]
    assert end["status"] == "ok" and end["rollbacks"] == 1


def test_trainer_aborts_after_max_rollbacks(tmp_path):
    """Repeated spikes past the budget end in a DivergenceError with a
    diagnostic payload and status=diverged — never a silent loop."""
    cfg = tiny_dense()
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    # second spike placed min_history past the first rollback's resume
    # point, so the re-armed detector has a settled window when it hits
    inj = FaultInjector([FaultSpec("loss_spike", at=5, scale=100.0),
                         FaultSpec("loss_spike", at=9, scale=100.0)])

    def make_data():
        return inj.wrap(DataPipeline(cfg, BATCH, SEQ, seed=0))

    log = EventLog.memory()
    tr = Trainer(build_model(cfg), tc, checkpoint_dir=str(tmp_path),
                 checkpoint_every=2,
                 supervisor=SupervisorConfig(spike_window=8, min_history=3,
                                             max_rollbacks=1),
                 telemetry=log, log_every=1, log_fn=lambda s: None)
    with pytest.raises(DivergenceError) as ei:
        tr.fit(make_data(), 14, data_factory=make_data)
    assert ei.value.diagnostics["reason"] == "loss_spike"
    end = [e for e in log.events if e["event"] == "run_end"][-1]
    assert end["status"] == "diverged"


def test_rollback_without_checkpoint_dir_raises():
    cfg = tiny_dense()
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)
    inj = FaultInjector([FaultSpec("loss_spike", at=5, scale=100.0)])

    def make_data():
        return inj.wrap(DataPipeline(cfg, BATCH, SEQ, seed=0))

    tr = Trainer(build_model(cfg), tc,
                 supervisor=SupervisorConfig(spike_window=8, min_history=3),
                 log_every=1000, log_fn=lambda s: None)
    with pytest.raises(DivergenceError, match="checkpoint_dir"):
        tr.fit(make_data(), 10, data_factory=make_data)


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

def test_preemption_handler_sets_flag_once():
    with PreemptionHandler(enabled=True, signals=(signal.SIGTERM,)) as h:
        assert not h.triggered
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.triggered
        assert h.signal_name == "SIGTERM"
        # a second delivery escalates instead of waiting another grace
        with pytest.raises(KeyboardInterrupt):
            os.kill(os.getpid(), signal.SIGTERM)
    # handlers restored on exit: SIGTERM outside the context is default


def test_preemption_handler_disabled_is_noop():
    with PreemptionHandler(enabled=False) as h:
        assert not h.triggered


def test_trainer_preempts_and_resumes_bit_exact(tmp_path):
    cfg = tiny_dense()
    tc = TrainConfig(optimizer="lamb", learning_rate=1e-3)

    class TermAfter:
        def __init__(self, inner, n):
            self.inner, self.n, self.i = inner, n, 0

        def __iter__(self):
            return self

        def __next__(self):
            if self.i == self.n:
                os.kill(os.getpid(), signal.SIGTERM)
            self.i += 1
            return next(self.inner)

    log = EventLog.memory()
    tr = Trainer(build_model(cfg), tc, checkpoint_dir=str(tmp_path),
                 checkpoint_every=100, preempt_grace=30.0, telemetry=log,
                 log_every=1, log_fn=lambda s: None)
    tr.fit(TermAfter(DataPipeline(cfg, BATCH, SEQ, seed=0), 4), 12)

    pe = [e for e in log.events if e["event"] == "preempt"][-1]
    end = [e for e in log.events if e["event"] == "run_end"][-1]
    assert end["status"] == "preempted"
    assert pe["saved"] and pe["signal"] == "SIGTERM"
    stopped_at = int(tr.state.step)
    assert stopped_at < 12
    assert checkpoint_step(latest_checkpoint(str(tmp_path))) == stopped_at

    resumed = Trainer(build_model(cfg), tc, checkpoint_dir=str(tmp_path),
                      checkpoint_every=100, resume=True,
                      log_every=1, log_fn=lambda s: None)
    h2 = resumed.fit(DataPipeline(cfg, BATCH, SEQ, seed=0), 12)

    ref = Trainer(build_model(cfg), tc, log_every=1, log_fn=lambda s: None)
    h3 = ref.fit(DataPipeline(cfg, BATCH, SEQ, seed=0), 12)
    tail2 = [{k: v for k, v in r.items() if k != "wall_s"}
             for r in h2 if r["step"] > stopped_at]
    tail3 = [{k: v for k, v in r.items() if k != "wall_s"}
             for r in h3 if r["step"] > stopped_at]
    assert tail2 and tail2 == tail3


# ---------------------------------------------------------------------------
# checkpoint rollback plumbing
# ---------------------------------------------------------------------------

def test_latest_checkpoint_max_step_bound(tmp_path):
    d = str(tmp_path)
    for s in (2, 4, 6):
        save_checkpoint(d, s, {"x": np.full((2,), s, np.float32)})
    assert checkpoint_step(latest_checkpoint(d)) == 6
    assert checkpoint_step(latest_checkpoint(d, max_step=5)) == 4
    assert checkpoint_step(latest_checkpoint(d, max_step=4)) == 4
    assert latest_checkpoint(d, max_step=1) is None


def test_discard_checkpoints_after(tmp_path):
    d = str(tmp_path)
    for s in (2, 4, 6):
        save_checkpoint(d, s, {"x": np.full((2,), s, np.float32)})
    removed = discard_checkpoints_after(d, 4)
    assert removed == ["step_00000006"]
    # LATEST re-pointed at the newest survivor — a later --resume must
    # never see the invalidated (possibly poisoned) checkpoint
    assert checkpoint_step(latest_checkpoint(d)) == 4
    assert discard_checkpoints_after(d, 10) == []


def test_discard_all_checkpoints_clears_latest(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 3, {"x": np.zeros((2,), np.float32)})
    discard_checkpoints_after(d, 0)
    assert latest_checkpoint(d) is None
